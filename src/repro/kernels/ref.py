"""Pure-jnp oracles for every Pallas kernel. Simple, obviously-correct,
O(S^2)/sequential implementations used by the allclose test sweeps.

These deliberately avoid the chunked/blocked tricks of the fast paths: the
flash oracle materializes scores; the SSM/WKV oracles scan one timestep at a
time.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """q [B,Sq,H,D]; k/v [B,Sk,Kv,D] (GQA). fp32 softmax."""
    B, Sq, H, D = q.shape
    Sk, Kv = k.shape[1], k.shape[2]
    g = H // Kv
    qg = q.reshape(B, Sq, Kv, g, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    s = s / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask, s, jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return o.reshape(B, Sq, H, D)


def ssd_ref(x, dt, A, Bm, Cm, init_state=None):
    """Sequential Mamba2/SSD recurrence (the definition).

    x [b,s,h,p]; dt [b,s,h] (post-softplus); A [h] (negative);
    Bm/Cm [b,s,n]. Returns (y [b,s,h,p], final_state [b,h,n,p]).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    S0 = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(S, inp):
        xt, dtt, Bt, Ct = inp          # [b,h,p],[b,h],[b,n],[b,n]
        dA = jnp.exp(dtt * A)          # [b,h]
        upd = jnp.einsum("bn,bh,bhp->bhnp", Bt, dtt, xt.astype(jnp.float32))
        S = S * dA[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", Ct, S)
        return S, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    S_fin, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), S_fin.astype(x.dtype)


def wkv6_ref(r, k, v, logw, u, init_state=None):
    """Sequential RWKV6 recurrence (the definition).

    r/k/v [B,S,H,c]; logw [B,S,H,c] (<=0); u [H,c].
    y_t = r_t . (S_t + diag(u) k_t v_t^T);  S_{t+1} = diag(w_t) S_t + k_t v_t^T
    Returns (y [B,S,H,c], final_state [B,H,c,c]).
    """
    B, S, H, c = r.shape
    S0 = (jnp.zeros((B, H, c, c), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(St, inp):
        rt, kt, vt, lwt = (t.astype(jnp.float32) for t in inp)  # [B,H,c]
        kv = jnp.einsum("bhc,bhd->bhcd", kt, vt)
        y = jnp.einsum("bhc,bhcd->bhd", rt,
                       St + u.astype(jnp.float32)[None, :, :, None] * kv)
        S_new = jnp.exp(lwt)[..., None] * St + kv
        return S_new, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, logw))
    S_fin, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), S_fin.astype(r.dtype)


def grouped_swiglu_ref(x, w_gate, w_up, w_down):
    """x [E,C,D]; w_* [E,D,F]/[E,F,D] -> [E,C,D]."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, w_gate))
    upj = jnp.einsum("ecd,edf->ecf", x, w_up)
    return jnp.einsum("ecf,efd->ecd", g * upj, w_down)


def ddpg_fused_ref(packed, batches, *, state_dim, action_dim, pad,
                   gamma, tau, actor_lr, critic_lr):
    """Sequential DDPG inner loop on the packed layout (the definition).

    One tuning session, no fleet axis. ``packed`` = (weights [4,L,P,P],
    biases [4,L,P], mom_w [2,2,L,P,P], mom_b [2,2,L,P], counts [2] i32) with
    nets ordered (actor, critic, actor_targ, critic_targ); ``batches`` =
    (sx, cx, s2x, r), each ``[U, B, P]`` / ``[U, B]`` — already padded and
    pre-gathered. Per §II-C, each update regresses the critic on the frozen
    targets' Bellman value, ascends Q(s, mu(s)) with the fresh critic, takes
    one Adam step per network (b1=0.9, b2=0.999, eps=1e-8 — ``optim.adam``'s
    defaults) and Polyak-averages the targets. Returns (packed',
    {critic_loss, actor_loss, q_mean} stacked over updates).
    """
    act_mask = (jnp.arange(pad) < action_dim).astype(jnp.float32)

    def mlp(w, b, x):
        h = jax.nn.relu(x @ w[0] + b[0])
        h = jax.nn.relu(h @ w[1] + b[1])
        return h @ w[2] + b[2]

    def mu_fwd(w, b, x):
        return jax.nn.sigmoid(mlp(w, b, x)) * act_mask[None, :]

    def q_fwd(w, b, x):
        return mlp(w, b, x)[:, 0]

    def with_actions(base, actions):
        rows = actions.shape[0]
        return base + jnp.concatenate(
            [jnp.zeros((rows, state_dim), jnp.float32),
             actions[:, :action_dim],
             jnp.zeros((rows, pad - state_dim - action_dim), jnp.float32)],
            axis=1)

    def adam(count, mu, nu, g, w, lr, b1=0.9, b2=0.999, eps=1e-8):
        count = count + 1
        cf = count.astype(jnp.float32)
        mu = b1 * mu + (1 - b1) * g
        nu = b2 * nu + (1 - b2) * jnp.square(g)
        w = w + (mu / (1 - b1 ** cf)) / (
            jnp.sqrt(nu / (1 - b2 ** cf)) + eps) * (-lr)
        return count, mu, nu, w

    def step(carry, batch):
        weights, biases, mom_w, mom_b, counts = carry
        sx, cx, s2x, r = batch

        a2 = mu_fwd(weights[2], biases[2], s2x)
        q_targ = jax.lax.stop_gradient(
            r + gamma * q_fwd(weights[3], biases[3], with_actions(s2x, a2)))

        def critic_loss_fn(wb):
            return jnp.mean(jnp.square(q_fwd(*wb, cx) - q_targ))

        critic_loss, (gcw, gcb) = jax.value_and_grad(critic_loss_fn)(
            (weights[1], biases[1]))
        ccnt, cmu_w, cnu_w, cw = adam(counts[1], mom_w[1, 0], mom_w[1, 1],
                                      gcw, weights[1], critic_lr)
        _, cmu_b, cnu_b, cb = adam(counts[1], mom_b[1, 0], mom_b[1, 1],
                                   gcb, biases[1], critic_lr)

        def actor_loss_fn(wb):
            mu = mu_fwd(*wb, sx)
            return -jnp.mean(q_fwd(cw, cb, with_actions(sx, mu)))

        actor_loss, (gaw, gab) = jax.value_and_grad(actor_loss_fn)(
            (weights[0], biases[0]))
        acnt, amu_w, anu_w, aw = adam(counts[0], mom_w[0, 0], mom_w[0, 1],
                                      gaw, weights[0], actor_lr)
        _, amu_b, anu_b, ab = adam(counts[0], mom_b[0, 0], mom_b[0, 1],
                                   gab, biases[0], actor_lr)

        atw = (1 - tau) * weights[2] + tau * aw
        atb = (1 - tau) * biases[2] + tau * ab
        ctw = (1 - tau) * weights[3] + tau * cw
        ctb = (1 - tau) * biases[3] + tau * cb
        q_mean = jnp.mean(q_fwd(cw, cb, cx))

        carry = (jnp.stack([aw, cw, atw, ctw]),
                 jnp.stack([ab, cb, atb, ctb]),
                 jnp.stack([jnp.stack([amu_w, anu_w]),
                            jnp.stack([cmu_w, cnu_w])]),
                 jnp.stack([jnp.stack([amu_b, anu_b]),
                            jnp.stack([cmu_b, cnu_b])]),
                 jnp.stack([acnt, ccnt]))
        return carry, (critic_loss, actor_loss, q_mean)

    packed, (cl, al, qm) = jax.lax.scan(step, packed, batches)
    return packed, {"critic_loss": cl, "actor_loss": al, "q_mean": qm}


def episode_fused_ref(op, *, spec):
    """Sequential whole-episode oracle (the definition of the megakernel).

    One session, no fleet axis: a plain Python loop over the T tuning steps,
    each running act -> env step -> scalarized reward -> FIFO store ->
    ``ddpg_fused_ref`` for the ``updates_per_step`` inner loop. No fusion
    barriers, no packed-across-steps trickery — parameters are sliced out of
    the packed layout with ordinary indexing every step. ``op`` is a
    per-session ``kernels.episode_fused.EpisodeOperands`` (leading session
    axis dropped); returns ``EpisodeOutputs``. Quantized knob indices come
    from the space's own coordinate maps (they are the definition of the
    action decode, not an implementation detail under test).
    """
    from repro.core.action_mapping import jax_coord_maps
    from repro.core.episode import _encode_restart
    from repro.kernels.ddpg_fused import _unpack_net
    from repro.kernels.episode_fused import EpisodeOutputs

    cfg, dims, space = spec.cfg, spec.dims, spec.space
    coord_maps = jax_coord_maps(space)
    params = jax.tree_util.tree_unflatten(spec.param_treedef,
                                          list(op.params))
    env_state = jax.tree_util.tree_unflatten(spec.env_treedef, list(op.env))
    packed = tuple(op.packed)
    bs, ba, br, bs2, next_slot, size = op.buffer
    learn_key, state_vec, objective = op.learn_key, op.state_vec, op.objective
    T = int(op.use_warmup.shape[0])
    m, k, P = space.dim, int(op.state_vec.shape[0]), dims.pad
    do_updates = spec.learn and spec.num_updates > 0

    tr_idx, tr_met, tr_rew, tr_obj, tr_rst = [], [], [], [], []
    for t in range(T):
        weights, biases = packed[0], packed[1]
        actor = _unpack_net(weights[0], biases[0], dims.actor_sizes)
        h = state_vec
        for li in range(len(actor) - 1):
            h = jax.nn.relu(h @ actor[li]["w"] + actor[li]["b"])
        policy = jax.nn.sigmoid(h @ actor[-1]["w"] + actor[-1]["b"])
        explored = jnp.clip(policy + op.noise[t], 0.0, 1.0)
        action = jnp.where(op.use_warmup[t],
                           jnp.clip(op.warmup[t], 0.0, 1.0), explored)
        action_idx = jnp.stack(
            [coord_maps[j](action[j])["idx"] for j in range(m)]
        ).astype(jnp.int32)

        env_state, metrics_vec, restart = spec.step_fn(params, env_state,
                                                       action, False)
        norm = jnp.where(op.span > 0,
                         jnp.clip((metrics_vec - op.lo) / op.span, 0.0, 1.0),
                         0.0)
        obj = jnp.float32(0.0)
        for j in range(k):
            obj = obj + op.w_vec[j] * norm[j]
        reward = (obj - objective) / jnp.maximum(objective, jnp.float32(1e-6))

        if spec.learn:
            i = next_slot
            bs = bs.at[i].set(state_vec.astype(bs.dtype))
            ba = ba.at[i].set(action.astype(ba.dtype))
            br = br.at[i].set(reward.astype(br.dtype))
            bs2 = bs2.at[i].set(norm.astype(bs2.dtype))
            next_slot = (i + 1) % bs.shape[0]
            size = jnp.minimum(size + 1, bs.shape[0])
        if do_updates:
            learn_key, kk = jax.random.split(learn_key)
            U, B = spec.num_updates, cfg.batch_size
            idx = jax.random.randint(kk, (U, B), 0, size)
            flat = idx.reshape(-1)

            def take(x):
                return x[flat].reshape(U, B, *x.shape[1:]).astype(
                    jnp.float32)

            s_b, a_b, r_b, s2_b = take(bs), take(ba), take(br), take(bs2)
            zk = jnp.zeros((U, B, P - k), jnp.float32)
            sx = jnp.concatenate([s_b, zk], axis=-1)
            s2x = jnp.concatenate([s2_b, zk], axis=-1)
            cx = jnp.concatenate(
                [s_b, a_b, jnp.zeros((U, B, P - k - m), jnp.float32)],
                axis=-1)
            packed, _ = ddpg_fused_ref(
                packed, (sx, cx, s2x, r_b), state_dim=k, action_dim=m,
                pad=P, gamma=cfg.gamma, tau=cfg.tau, actor_lr=cfg.actor_lr,
                critic_lr=cfg.critic_lr)

        tr_idx.append(action_idx)
        tr_met.append(metrics_vec)
        tr_rew.append(reward)
        tr_obj.append(obj)
        tr_rst.append(_encode_restart(restart))
        state_vec, objective = norm, obj

    return EpisodeOutputs(
        tuple(jax.tree_util.tree_leaves(env_state)), packed,
        (bs, ba, br, bs2, next_slot, size), learn_key, state_vec, objective,
        jnp.stack(tr_idx), jnp.stack(tr_met), jnp.stack(tr_rew),
        jnp.stack(tr_obj), jnp.stack(tr_rst))
