"""Pure-jnp oracles for every Pallas kernel. Simple, obviously-correct,
O(S^2)/sequential implementations used by the allclose test sweeps.

These deliberately avoid the chunked/blocked tricks of the fast paths: the
flash oracle materializes scores; the SSM/WKV oracles scan one timestep at a
time.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, causal: bool = True):
    """q [B,Sq,H,D]; k/v [B,Sk,Kv,D] (GQA). fp32 softmax."""
    B, Sq, H, D = q.shape
    Sk, Kv = k.shape[1], k.shape[2]
    g = H // Kv
    qg = q.reshape(B, Sq, Kv, g, D)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k).astype(jnp.float32)
    s = s / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), k=Sk - Sq)
        s = jnp.where(mask, s, jnp.finfo(jnp.float32).min)
    p = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p, v)
    return o.reshape(B, Sq, H, D)


def ssd_ref(x, dt, A, Bm, Cm, init_state=None):
    """Sequential Mamba2/SSD recurrence (the definition).

    x [b,s,h,p]; dt [b,s,h] (post-softplus); A [h] (negative);
    Bm/Cm [b,s,n]. Returns (y [b,s,h,p], final_state [b,h,n,p]).
    """
    b, s, h, p = x.shape
    n = Bm.shape[-1]
    S0 = (jnp.zeros((b, h, n, p), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(S, inp):
        xt, dtt, Bt, Ct = inp          # [b,h,p],[b,h],[b,n],[b,n]
        dA = jnp.exp(dtt * A)          # [b,h]
        upd = jnp.einsum("bn,bh,bhp->bhnp", Bt, dtt, xt.astype(jnp.float32))
        S = S * dA[:, :, None, None] + upd
        y = jnp.einsum("bn,bhnp->bhp", Ct, S)
        return S, y

    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    S_fin, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), S_fin.astype(x.dtype)


def wkv6_ref(r, k, v, logw, u, init_state=None):
    """Sequential RWKV6 recurrence (the definition).

    r/k/v [B,S,H,c]; logw [B,S,H,c] (<=0); u [H,c].
    y_t = r_t . (S_t + diag(u) k_t v_t^T);  S_{t+1} = diag(w_t) S_t + k_t v_t^T
    Returns (y [B,S,H,c], final_state [B,H,c,c]).
    """
    B, S, H, c = r.shape
    S0 = (jnp.zeros((B, H, c, c), jnp.float32) if init_state is None
          else init_state.astype(jnp.float32))

    def step(St, inp):
        rt, kt, vt, lwt = (t.astype(jnp.float32) for t in inp)  # [B,H,c]
        kv = jnp.einsum("bhc,bhd->bhcd", kt, vt)
        y = jnp.einsum("bhc,bhcd->bhd", rt,
                       St + u.astype(jnp.float32)[None, :, :, None] * kv)
        S_new = jnp.exp(lwt)[..., None] * St + kv
        return S_new, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, logw))
    S_fin, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(r.dtype), S_fin.astype(r.dtype)


def grouped_swiglu_ref(x, w_gate, w_up, w_down):
    """x [E,C,D]; w_* [E,D,F]/[E,F,D] -> [E,C,D]."""
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", x, w_gate))
    upj = jnp.einsum("ecd,edf->ecf", x, w_up)
    return jnp.einsum("ecf,efd->ecd", g * upj, w_down)
