"""Grouped (per-expert) matmul — Pallas TPU kernel for the MoE hot spot.

out[e] = x[e] @ w[e] for e in experts, blocked (bc x bf x bd) with a fp32
VMEM accumulator across the contraction grid dim (sequential minor dim).
Block shapes default to MXU-aligned 128s; callers pad C/D/F to multiples.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref):
    kd = pl.program_id(3)
    nd = pl.num_programs(3)

    @pl.when(kd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[0].astype(jnp.float32),
                            w_ref[0].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(kd == nd - 1)
    def _fin():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def gmm(x, w, *, block_c: int = 128, block_f: int = 128, block_d: int = 512,
        interpret: bool = False):
    """x [E, C, D] @ w [E, D, F] -> [E, C, F]."""
    E, C, D = x.shape
    F = w.shape[-1]
    block_c = min(block_c, C)
    block_f = min(block_f, F)
    block_d = min(block_d, D)
    assert C % block_c == 0 and F % block_f == 0 and D % block_d == 0
    grid = (E, C // block_c, F // block_f, D // block_d)
    return pl.pallas_call(
        _gmm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_c, block_d),
                         lambda e, i, j, kd: (e, i, kd)),
            pl.BlockSpec((1, block_d, block_f),
                         lambda e, i, j, kd: (e, kd, j)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_f),
                               lambda e, i, j, kd: (e, i, j)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_c, block_f), jnp.float32)],
        interpret=interpret,
    )(x, w)
