"""Chunked Mamba2 / SSD scan — Pallas TPU kernel.

TPU adaptation of the SSD algorithm: the (batch*head) axis is the outer grid
dim, chunks are the sequential minor grid dim, and the running SSM state
[N, P] lives in a fp32 VMEM scratch that persists across chunk iterations.
Per chunk everything is MXU matmuls: the [Q,Q] masked-decay score matmul
(intra-chunk), the C @ state matmul (inter-chunk) and the B^T @ (dt*x) state
update. All decay exponents are <= 0 — no overflow.

Layouts: x [BH, S, P]; dt [BH, S] (post-softplus); A [BH] (negative);
Bm/Cm [B, S, N] (G=1 shared across heads; index-mapped via bh // H).
Outputs: y [BH, S, P], final_state [BH, N, P].
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(a_ref, x_ref, dt_ref, b_ref, c_ref, y_ref, s_out_ref,
                state_ref, *, chunk):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)                       # (Q, P)
    dt = dt_ref[0].astype(jnp.float32)                     # (Q,)
    Bm = b_ref[0].astype(jnp.float32)                      # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)                      # (Q, N)
    A = a_ref[0].astype(jnp.float32)                       # scalar

    a = dt * A                                             # (Q,) log-decay
    cum = jnp.cumsum(a)                                    # inclusive
    # intra-chunk: decay(t,s) = exp(cum[t]-cum[s]) for s<=t
    dec = jnp.exp(cum[:, None] - cum[None, :])
    mask = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    dec = jnp.where(mask, dec, 0.0)
    cb = jnp.dot(Cm, Bm.T, preferred_element_type=jnp.float32)   # (Q, Q)
    scores = cb * dec * dt[None, :]
    y = jnp.dot(scores, x, preferred_element_type=jnp.float32)   # (Q, P)

    # inter-chunk: y += (C @ S_prev) * exp(cum[t])
    S_prev = state_ref[...]                                # (N, P)
    y = y + jnp.dot(Cm, S_prev,
                    preferred_element_type=jnp.float32) * jnp.exp(cum)[:, None]
    y_ref[0] = y.astype(y_ref.dtype)

    # state update: S = exp(a_tot) S_prev + B^T @ (decay_to_end * dt * x)
    a_tot = cum[chunk - 1]
    w = jnp.exp(a_tot - cum) * dt                          # (Q,)
    S_new = jnp.exp(a_tot) * S_prev + jnp.dot(
        Bm.T, x * w[:, None], preferred_element_type=jnp.float32)
    state_ref[...] = S_new

    @pl.when(ci == nc - 1)
    def _fin():
        s_out_ref[0] = S_new.astype(s_out_ref.dtype)


def ssd_scan(x, dt, A, Bm, Cm, *, heads: int, chunk: int = 128,
             interpret: bool = False):
    """x [BH,S,P]; dt [BH,S]; A [BH]; Bm/Cm [B,S,N]; heads = H (for the
    bh -> b index map). Returns (y [BH,S,P], state [BH,N,P])."""
    BH, S, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1,), lambda bh, c: (bh,)),
            pl.BlockSpec((1, chunk, P), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, chunk), lambda bh, c: (bh, c)),
            pl.BlockSpec((1, chunk, N), lambda bh, c: (bh // heads, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda bh, c: (bh // heads, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, P), lambda bh, c: (bh, c, 0)),
            pl.BlockSpec((1, N, P), lambda bh, c: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, P), x.dtype),
            jax.ShapeDtypeStruct((BH, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(A, x, dt, Bm, Cm)
    return y, state
