"""Whole-episode megakernel: the Fig. 1 loop as ONE Pallas kernel per chunk.

``kernels/ddpg_fused.py`` fused the Table-III inner loop (96 sequential DDPG
updates); the rest of the per-step pipeline — act, env transition, reward
scalarization, FIFO replay store — still round-tripped through HBM/XLA
between fusion islands. This module fuses the ENTIRE episode: one kernel
program instance runs all T tuning steps for one session, with the packed
learner state (all four parameter sets + both Adam moment sets), the replay
window, and the env state resident in VMEM start to finish.

  * the grid is the chunk's session axis — each program instance owns one
    session's episode; ``input_output_aliases`` carries every stateful
    operand (env leaves, packed learner, FIFO storage + cursors, learn key,
    state vector, objective) in place across the call;
  * per step the body mirrors ``core.episode._build_episode.one_step``
    op-for-op: the actor forward runs on the REAL-size slices of the packed
    weights (packing is exact zero placement, so the slices are bitwise the
    unpacked parameters — padded [P, P] GEMMs would regroup the reduction
    tree and break decision exactness), the env ``step_fn`` runs unchanged
    (the pure-JAX Lustre/synthetic models are ordinary jnp + threefry code,
    which Pallas interpret mode discharges verbatim), and the learner is the
    same packed ``fori_loop`` the PR-4 kernel runs — kept packed across
    steps instead of packing/unpacking per step (exact: pack∘unpack is the
    identity on the real regions and the padded regions are a zero fixed
    point, pinned by tests/test_ddpg_fused.py);
  * the per-phase ``fusion_barrier`` islands of the scan engine are kept
    when the body is compiled by XLA (interpret mode and the
    ``episode_fused_xla`` twin) so cross-program float drift stays within
    ulps, and dropped when Mosaic compiles the body for real
    (``optimization_barrier`` has no Mosaic lowering; inside one kernel
    there is no cross-phase fusion to suppress anyway).

Equivalence ladder (PR 4's template, pinned by tests/test_megakernel.py):
pure-jnp oracle (``kernels.ref.episode_fused_ref``) ≤ a few f32 ulps; XLA
twin (``episode_fused_xla``) bitwise vs interpret mode; decision trajectory
EXACT vs ``run_episode_scan`` when the scan engine runs the same packed
learner (``REPRO_KERNELS=interpret``/``pallas``).

VMEM fit: ``roofline.vmem.check_episode_vmem_fit`` models the per-instance
residency (packed learner + replay window + minibatch workspace + trace +
exploration inputs) and rejects oversized (chunk, capacity, space) combos
with an actionable error BEFORE the kernel is built — a Pallas OOM names a
buffer, not a remedy. The check runs for both compiled and interpret modes
so the contract is testable off-TPU.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.ddpg_fused import (NUM_LAYERS, PackedDims, _unpack_net,
                                      pack_minibatches, packed_update)


class EpisodeKernelSpec(NamedTuple):
    """Static episode-kernel configuration (hashable where it matters:
    ``step_fn``/``space``/``cfg`` are the same objects the episode cache
    keys on; treedefs reconstruct the env/param pytrees inside the body)."""

    step_fn: Any
    space: Any
    cfg: Any                  # core.ddpg.DDPGConfig
    learn: bool
    num_updates: int
    dims: PackedDims
    param_treedef: Any
    env_treedef: Any


class EpisodeOperands(NamedTuple):
    """Flat operand bundle, every array with a leading session axis [N, ...]
    (drop it for the per-session body/oracle). ``params``/``env`` are tuples
    of pytree leaves (see ``EpisodeKernelSpec`` treedefs); ``packed`` is the
    ``pack_params`` 5-tuple; ``buffer`` is (s, a, r, s2, next_slot, size)."""

    use_warmup: jnp.ndarray   # [N, T] bool
    warmup: jnp.ndarray       # [N, T, m] f32
    noise: jnp.ndarray        # [N, T, m] f32
    w_vec: jnp.ndarray        # [N, k] f32
    lo: jnp.ndarray           # [N, k] f32
    span: jnp.ndarray         # [N, k] f32
    params: tuple             # env-model param leaves
    env: tuple                # env-state leaves
    packed: tuple             # (weights, biases, mom_w, mom_b, counts)
    buffer: tuple             # (s, a, r, s2, next_slot, size)
    learn_key: jnp.ndarray    # [N, 2] u32
    state_vec: jnp.ndarray    # [N, k] f32
    objective: jnp.ndarray    # [N] f32


class EpisodeOutputs(NamedTuple):
    """Episode results: carried state plus the compact per-step trace
    (actions as i32 knob indices — callers cast to ``space.index_dtype()`` —
    and restarts as the int32 fixed point of ``core.episode``)."""

    env: tuple
    packed: tuple
    buffer: tuple
    learn_key: jnp.ndarray
    state_vec: jnp.ndarray
    objective: jnp.ndarray
    action_idx: jnp.ndarray   # [T, m] i32
    metrics: jnp.ndarray      # [T, k] f32
    rewards: jnp.ndarray      # [T] f32
    objectives: jnp.ndarray   # [T] f32
    restarts: jnp.ndarray     # [T] i32 fixed point


# number of aliased state operands besides the env leaves: packed (5) +
# buffer (6) + learn_key + state_vec + objective
_N_STATE_OPERANDS = 14


def _episode_body(spec: EpisodeKernelSpec, op: EpisodeOperands,
                  barriers: bool) -> EpisodeOutputs:
    """One session's whole episode (shared by the kernel body, the XLA twin
    and — vmapped — nothing else). ``barriers=True`` keeps the scan engine's
    per-phase ``fusion_barrier`` islands (XLA-compiled paths); the Mosaic
    path drops them."""
    from repro.core.action_mapping import jax_coord_maps
    from repro.core.ddpg import gather_minibatches, sample_minibatch_indices
    from repro.core.episode import _encode_restart
    from repro.envs.base import barriered_step, fusion_barrier

    cfg, dims, space = spec.cfg, spec.dims, spec.space
    learn = spec.learn
    num_updates = spec.num_updates
    do_updates = learn and num_updates > 0
    coord_maps = jax_coord_maps(space)
    T = op.use_warmup.shape[0]
    m = space.dim
    k = op.state_vec.shape[0]
    bar = fusion_barrier if barriers else (lambda t: t)

    params = jax.tree_util.tree_unflatten(spec.param_treedef,
                                          list(op.params))
    act_mask = (jax.lax.broadcasted_iota(jnp.int32, (1, dims.pad), 1)
                < dims.action_dim).astype(jnp.float32)

    def env_step(env_state, action):
        if barriers:
            return barriered_step(spec.step_fn, params, env_state, action,
                                  False)
        return spec.step_fn(params, env_state, action, False)

    def one_step(t, carry):
        (env_leaves, packed, buf, learn_key, state_vec, objective,
         tr_idx, tr_met, tr_rew, tr_obj, tr_rst) = carry
        weights, biases, mom_w, mom_b, counts = packed
        env_state = jax.tree_util.tree_unflatten(spec.env_treedef,
                                                 list(env_leaves))
        take = functools.partial(jax.lax.dynamic_index_in_dim, index=t,
                                 axis=0, keepdims=False)
        use_warmup, warmup_a, noise = (take(op.use_warmup), take(op.warmup),
                                       take(op.noise))

        # act — on the REAL-size weight slices (bitwise the unpacked actor;
        # see module docstring), same phase order as the scan engine
        actor_p = _unpack_net(weights[0], biases[0], dims.actor_sizes)
        actor_p, sv = bar((actor_p, state_vec))
        h = sv
        for li in range(NUM_LAYERS - 1):
            h = jax.nn.relu(h @ actor_p[li]["w"] + actor_p[li]["b"])
        policy = bar(jax.nn.sigmoid(h @ actor_p[NUM_LAYERS - 1]["w"]
                                    + actor_p[NUM_LAYERS - 1]["b"]))
        explored = jnp.clip(policy + noise, 0.0, 1.0)
        action = jnp.where(use_warmup, jnp.clip(warmup_a, 0.0, 1.0),
                           explored)
        action_idx = jnp.stack(
            [coord_maps[j](action[j])["idx"] for j in range(m)]
        ).astype(jnp.int32)

        # env transition + state normalization
        env_state, metrics_vec, restart = env_step(env_state, action)
        norm = jnp.where(op.span > 0,
                         jnp.clip((metrics_vec - op.lo) / op.span, 0.0, 1.0),
                         0.0)

        # objective: serial float32 fold in state order (Scalarizer order)
        obj = jnp.float32(0.0)
        for j in range(k):
            obj = obj + op.w_vec[j] * norm[j]
        reward = (obj - objective) / jnp.maximum(objective, jnp.float32(1e-6))

        bs, ba, br, bs2, next_slot, size = buf
        if learn:  # observe: FIFO write, exactly ReplayBuffer.add
            capacity = bs.shape[0]
            i = next_slot
            buf = (bs.at[i].set(state_vec.astype(bs.dtype)),
                   ba.at[i].set(action.astype(ba.dtype)),
                   br.at[i].set(reward.astype(br.dtype)),
                   bs2.at[i].set(norm.astype(bs2.dtype)),
                   (i + 1) % capacity,
                   jnp.minimum(size + 1, capacity))
        if do_updates:
            # store-before-learn: the FIFO write above ran in this same
            # step, so size >= 1 and minibatch sampling never sees an empty
            # window (the sample_minibatch_indices invariant)
            learn_key, kk = jax.random.split(learn_key)
            packed_in, buf_in, kk = bar((packed, buf, kk))
            idx = sample_minibatch_indices(kk, num_updates, cfg.batch_size,
                                           buf_in[5])
            batches = gather_minibatches(tuple(buf_in[:4]), idx)
            batches = tuple(b.astype(jnp.float32) for b in batches)
            sx, cx, s2x, r = pack_minibatches(batches, dims)

            def upd(u, ucarry):
                pk, met = ucarry
                batch = tuple(
                    jax.lax.dynamic_index_in_dim(x, u, 0, keepdims=False)
                    for x in (sx, cx, s2x, r))
                pk, (cl, al, qm) = packed_update(
                    pk, batch, dims, cfg.gamma, cfg.tau, cfg.actor_lr,
                    cfg.critic_lr, act_mask)
                met = jax.lax.dynamic_update_index_in_dim(
                    met, jnp.stack([cl, al, qm]), u, 0)
                return pk, met

            packed, _ = bar(jax.lax.fori_loop(
                0, num_updates, upd,
                (packed_in, jnp.zeros((num_updates, 3), jnp.float32))))

        tr_idx = jax.lax.dynamic_update_index_in_dim(tr_idx, action_idx, t, 0)
        tr_met = jax.lax.dynamic_update_index_in_dim(tr_met, metrics_vec,
                                                     t, 0)
        tr_rew = jax.lax.dynamic_update_index_in_dim(tr_rew, reward, t, 0)
        tr_obj = jax.lax.dynamic_update_index_in_dim(tr_obj, obj, t, 0)
        tr_rst = jax.lax.dynamic_update_index_in_dim(
            tr_rst, _encode_restart(restart), t, 0)
        env_leaves = tuple(jax.tree_util.tree_leaves(env_state))
        return (env_leaves, packed, buf, learn_key, norm, obj,
                tr_idx, tr_met, tr_rew, tr_obj, tr_rst)

    init = (tuple(op.env), tuple(op.packed), tuple(op.buffer), op.learn_key,
            op.state_vec, op.objective,
            jnp.zeros((T, m), jnp.int32), jnp.zeros((T, k), jnp.float32),
            jnp.zeros((T,), jnp.float32), jnp.zeros((T,), jnp.float32),
            jnp.zeros((T,), jnp.int32))
    (env_leaves, packed, buf, learn_key, state_vec, objective,
     tr_idx, tr_met, tr_rew, tr_obj, tr_rst) = jax.lax.fori_loop(
        0, T, one_step, init)
    return EpisodeOutputs(env_leaves, packed, buf, learn_key, state_vec,
                          objective, tr_idx, tr_met, tr_rew, tr_obj, tr_rst)


def _flat_outputs(outs: EpisodeOutputs) -> list:
    return (list(outs.env) + list(outs.packed) + list(outs.buffer)
            + [outs.learn_key, outs.state_vec, outs.objective,
               outs.action_idx, outs.metrics, outs.rewards, outs.objectives,
               outs.restarts])


def _unflatten_outputs(flat: list, n_env: int) -> EpisodeOutputs:
    env = tuple(flat[:n_env])
    packed = tuple(flat[n_env:n_env + 5])
    buffer = tuple(flat[n_env + 5:n_env + 11])
    rest = flat[n_env + 11:]
    return EpisodeOutputs(env, packed, buffer, *rest)


def episode_fused_learn(operands: EpisodeOperands, *,
                        spec: EpisodeKernelSpec,
                        interpret: bool = False) -> EpisodeOutputs:
    """Run the whole chunk of episodes as ONE Pallas kernel.

    Every array in ``operands`` carries a leading session axis N; the grid
    is (N,) — one program instance per session's full T-step episode. All
    stateful operands are aliased to the outputs, so callers must treat
    them as consumed. Raises ``ValueError`` (via the roofline VMEM-fit
    check) before building an oversized kernel.
    """
    from repro.roofline.vmem import check_episode_vmem_fit

    n, T = operands.use_warmup.shape
    capacity = operands.buffer[0].shape[1]
    env_bytes = sum(int(x.nbytes) // n for x in operands.env)
    check_episode_vmem_fit(
        chunk=n, steps=T, capacity=capacity, state_dim=spec.cfg.state_dim,
        action_dim=spec.cfg.action_dim, hidden=spec.cfg.hidden,
        num_updates=spec.num_updates if spec.learn else 0,
        batch_size=spec.cfg.batch_size, pad=spec.dims.pad,
        env_state_bytes=env_bytes)

    flat_in, in_tree = jax.tree_util.tree_flatten(operands)
    n_in = len(flat_in)
    n_env = len(operands.env)
    i0 = 6 + len(operands.params)   # first aliased (env-state) operand
    m, k = spec.space.dim, operands.state_vec.shape[1]

    def bspec(shape):
        nd = len(shape)
        return pl.BlockSpec((1, *shape), lambda i, nd=nd: (i,) + (0,) * nd)

    def cspec(shape):
        # session-invariant constant: every grid instance reads block 0
        nd = len(shape)
        return pl.BlockSpec((1, *shape), lambda i, nd=nd: (0,) + (0,) * nd)

    def like(x):
        return jax.ShapeDtypeStruct(x.shape, x.dtype)

    # Pallas kernels must be closed: the body's captured constants (the
    # space's quantization tables, env-model surface coefficients, ...) are
    # hoisted by tracing the body once and lifting the jaxpr consts into
    # session-invariant kernel operands.
    def body_flat(*vals):
        op1 = jax.tree_util.tree_unflatten(in_tree, list(vals))
        return tuple(_flat_outputs(_episode_body(spec, op1,
                                                 barriers=interpret)))

    example = [jax.ShapeDtypeStruct(x.shape[1:], x.dtype) for x in flat_in]
    body_jaxpr = jax.make_jaxpr(body_flat)(*example)
    consts = [jnp.asarray(cv) for cv in body_jaxpr.consts]
    n_consts = len(consts)

    def closed_body(*vals_and_consts):
        return jax.core.eval_jaxpr(body_jaxpr.jaxpr,
                                   list(vals_and_consts[n_in:]),
                                   *vals_and_consts[:n_in])

    aliased = flat_in[i0:]
    trace_shapes = [jax.ShapeDtypeStruct((n, T, m), jnp.int32),
                    jax.ShapeDtypeStruct((n, T, k), jnp.float32),
                    jax.ShapeDtypeStruct((n, T), jnp.float32),
                    jax.ShapeDtypeStruct((n, T), jnp.float32),
                    jax.ShapeDtypeStruct((n, T), jnp.int32)]
    in_specs = ([bspec(x.shape[1:]) for x in flat_in]
                + [cspec(cv.shape) for cv in consts])
    out_shape = [like(x) for x in aliased] + trace_shapes
    out_specs = [bspec(tuple(s.shape[1:])) for s in out_shape]

    def kernel(*refs):
        vals = [r[0] for r in refs[:n_in]]
        cvals = [r[0] for r in refs[n_in:n_in + n_consts]]
        flat_out = closed_body(*vals, *cvals)
        for r, v in zip(refs[n_in + n_consts:], flat_out):
            r[0] = v

    # rough cost: the learner dominates (15 network passes per update);
    # the env/act phases add a handful of tiny matvecs per step
    p = spec.dims.pad
    u = spec.num_updates if spec.learn else 0
    gemm_flops = 2 * spec.cfg.batch_size * p * p * NUM_LAYERS
    cost = pl.CostEstimate(
        flops=int(n * T * max(u, 1) * 15 * gemm_flops),
        bytes_accessed=int(sum(x.nbytes for x in aliased) * 3),
        transcendentals=int(n * T * max(u, 1) * spec.cfg.batch_size * p * 2))
    flat_out = pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases={i0 + j: j for j in range(len(aliased))},
        cost_estimate=cost,
        interpret=interpret,
    )(*flat_in, *(cv[None] for cv in consts))
    return _unflatten_outputs(list(flat_out), n_env)


def episode_fused_xla(operands: EpisodeOperands, *,
                      spec: EpisodeKernelSpec) -> EpisodeOutputs:
    """The megakernel's computation compiled by XLA: the identical
    per-session body vmapped over the session axis — same packed learner,
    same fusion islands, same float32 op order. The kernel's validation
    twin, and the megakernel formulation's CPU/GPU fallback."""
    return jax.vmap(lambda op: _episode_body(spec, op, barriers=True))(
        operands)
