"""Chunked RWKV6 WKV recurrence — Pallas TPU kernel.

Same chunking strategy as the SSD kernel (sequential chunk grid dim, fp32
state scratch [c, c] persisting across chunks), but the decay is per-channel
and data-dependent, so the intra-chunk decay tensor is [Q, Q, c] (built from
log-space cumsums; every exponent <= 0 — no overflow) and the score reduction
is an einsum over the channel dim.

Layouts: r/k/v/logw [BH, S, c]; u [BH, c]. Outputs: y [BH, S, c],
final state [BH, c, c] (state[c_key, c_value]).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, s_out_ref,
                state_ref, *, chunk):
    ci = pl.program_id(1)
    nc = pl.num_programs(1)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)                       # (Q, c)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)                     # (Q, c) <= 0
    u = u_ref[0].astype(jnp.float32)                       # (c,)

    cum = jnp.cumsum(lw, axis=0)                           # inclusive (Q, c)
    cum_prev = cum - lw                                    # exclusive

    # intra-chunk strict-lower decays: exp(cum_prev[t] - cum[s]), s < t
    dec = jnp.exp(jnp.minimum(cum_prev[:, None, :] - cum[None, :, :], 0.0))
    strict = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) > \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    dec = jnp.where(strict[:, :, None], dec, 0.0)          # (Q, Q, c)
    scores = jnp.einsum("tc,tsc,sc->ts", r, dec, k)        # (Q, Q)
    y = jnp.dot(scores, v, preferred_element_type=jnp.float32)
    diag = jnp.sum(r * u[None, :] * k, axis=-1)            # (Q,)
    y = y + diag[:, None] * v

    # inter-chunk: y += (r * exp(cum_prev)) @ S_prev
    S_prev = state_ref[...]                                # (c, c)
    y = y + jnp.dot(r * jnp.exp(cum_prev), S_prev,
                    preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    # state: S = diag(exp(cum_tot)) S_prev + sum_s exp(cum_tot - cum[s]) k_s v_s^T
    cum_tot = cum[chunk - 1]                               # (c,)
    kd = k * jnp.exp(cum_tot[None, :] - cum)               # (Q, c)
    S_new = jnp.exp(cum_tot)[:, None] * S_prev + jnp.dot(
        kd.T, v, preferred_element_type=jnp.float32)
    state_ref[...] = S_new

    @pl.when(ci == nc - 1)
    def _fin():
        s_out_ref[0] = S_new.astype(s_out_ref.dtype)


def wkv6_scan(r, k, v, logw, u, *, chunk: int = 64, interpret: bool = False):
    """r/k/v/logw [BH, S, c]; u [BH, c]. Returns (y, state [BH, c, c])."""
    BH, S, c = r.shape
    assert S % chunk == 0
    nc = S // chunk
    y, state = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk),
        grid=(BH, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, c), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, c), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, c), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, c), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, c), lambda bh, ci: (bh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, c), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, c, c), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, c), r.dtype),
            jax.ShapeDtypeStruct((BH, c, c), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((c, c), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
    return y, state
