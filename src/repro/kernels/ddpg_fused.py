"""Fused DDPG inner loop — Pallas TPU kernel for the tuning hot path.

The reproduction's hot spot is not a transformer layer: it is the paper's
Table III inner loop — ``updates_per_step`` (96) *sequential* DDPG updates of
tiny (64, 64)-hidden MLPs at minibatch 16, repeated for every tuning session
in a fleet. All four parameter sets (actor, critic, and their Polyak targets)
plus both Adam moment sets total a few hundred KB, so the entire learner
state fits in VMEM with room to spare; what kills throughput off-TPU is
round-tripping those parameters through memory between 96 latency-dominated
micro-updates.

``ddpg_fused_learn`` runs the whole inner loop as ONE kernel:

  * the grid is the fleet session axis — each program instance owns one
    session's learner and runs its 96 updates start to finish;
  * the four parameter sets and both Adam moment sets are loaded into VMEM
    once, carried through a ``fori_loop`` over updates, and written back
    once (``input_output_aliases`` makes the update in-place);
  * minibatches are pre-gathered on the host side of the call (one take per
    buffer array — see ``core.ddpg.gather_minibatches``) and handed to the
    kernel as ``[num_updates, batch, P]`` blocks, so the kernel reads them
    with a cheap dynamic index per update, no gathers inside.

Packed layout (``pack_params`` / ``unpack_params``): every layer is
zero-padded to a ``[P, P]`` tile (``P = pad_width(...)``, a multiple of 64),
and the four networks are stacked on a leading net axis:

    weights  [4, L, P, P]   nets: actor, critic, actor_targ, critic_targ
    biases   [4, L, P]
    mom_w    [2, 2, L, P, P] (net: actor/critic) x (moment: mu/nu)
    mom_b    [2, 2, L, P]
    counts   [2] i32         Adam step counts (actor, critic)

Zero padding is self-preserving: padded input rows and output columns get
exactly-zero gradients (the sigmoid head is masked to the real action lanes,
the critic reads lane 0 only), so Adam moments and Polyak targets stay zero
in the padding forever — pinned by tests/test_ddpg_fused.py.

The same packed update step (``packed_update``) is also compiled directly by
XLA (``ddpg_fused_xla``) — that is the "fleet-batched GEMM" formulation of
the fallback. On CPU the blocked [P, P] GEMMs lose to the unpadded scan
(see benchmarks/fleet_throughput.py::bench_learner_paths), so the CPU
default stays ``core.ddpg``'s pre-gathered scan; the packed path is the
kernel's oracle-validated twin and the TPU shape of the computation.

Adam hyperparameters are ``repro.optim.adam``'s defaults (b1=0.9, b2=0.999,
eps=1e-8) — the only transforms ``core.ddpg`` ever builds; the dispatcher
(``kernels.ops.ddpg_inner_loop``) verifies the optimizer-state structure
before routing here.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_ADAM_B1 = 0.9
_ADAM_B2 = 0.999
_ADAM_EPS = 1e-8
NUM_NETS = 4      # actor, critic, actor_targ, critic_targ
NUM_LAYERS = 3    # two hidden layers + head (the paper's MLPs)


class PackedDims(NamedTuple):
    """Static shape info for the packed layout (hashable, jit-friendly)."""

    state_dim: int
    action_dim: int
    hidden: tuple
    pad: int

    @property
    def actor_sizes(self) -> tuple:
        return (self.state_dim, *self.hidden, self.action_dim)

    @property
    def critic_sizes(self) -> tuple:
        return (self.state_dim + self.action_dim, *self.hidden, 1)


def pad_width(state_dim: int, action_dim: int, hidden: tuple) -> int:
    """Lane width P: every layer dimension padded up to a multiple of 64."""
    widest = max(state_dim + action_dim, action_dim, 1, *hidden)
    return max(64, -(-widest // 64) * 64)


def packed_dims(state_dim: int, action_dim: int, hidden: tuple) -> PackedDims:
    if len(hidden) != NUM_LAYERS - 1:
        raise ValueError(
            f"packed layout supports {NUM_LAYERS - 1} hidden layers, "
            f"got hidden={hidden!r}")
    return PackedDims(state_dim, action_dim, tuple(hidden),
                      pad_width(state_dim, action_dim, hidden))


def _pack_net(layers, dims: PackedDims):
    """list of {'w','b'} -> (w [L,P,P], b [L,P]), zero-padded."""
    p = dims.pad
    ws, bs = [], []
    for layer in layers:
        win, wout = layer["w"].shape[-2:]
        ws.append(jnp.zeros((p, p), jnp.float32).at[:win, :wout]
                  .set(layer["w"]))
        bs.append(jnp.zeros((p,), jnp.float32).at[:wout].set(layer["b"]))
    return jnp.stack(ws), jnp.stack(bs)


def _unpack_net(w, b, sizes):
    """(w [L,P,P], b [L,P]) -> list of {'w','b'} at the real layer sizes."""
    return [{"w": w[i, :fin, :fout], "b": b[i, :fout]}
            for i, (fin, fout) in enumerate(zip(sizes[:-1], sizes[1:]))]


def pack_params(actor, critic, actor_targ, critic_targ,
                actor_mu, actor_nu, critic_mu, critic_nu,
                actor_count, critic_count, dims: PackedDims):
    """Pytree learner state -> (weights, biases, mom_w, mom_b, counts)."""
    nets = [_pack_net(n, dims)
            for n in (actor, critic, actor_targ, critic_targ)]
    weights = jnp.stack([w for w, _ in nets])
    biases = jnp.stack([b for _, b in nets])
    moms = [[_pack_net(m, dims) for m in (mu, nu)]
            for mu, nu in ((actor_mu, actor_nu), (critic_mu, critic_nu))]
    mom_w = jnp.stack([jnp.stack([w for w, _ in net]) for net in moms])
    mom_b = jnp.stack([jnp.stack([b for _, b in net]) for net in moms])
    counts = jnp.stack([jnp.asarray(actor_count, jnp.int32),
                        jnp.asarray(critic_count, jnp.int32)])
    return weights, biases, mom_w, mom_b, counts


def unpack_params(weights, biases, mom_w, mom_b, counts, dims: PackedDims):
    """Inverse of ``pack_params`` -> dict of pytrees at the real sizes."""
    sizes = (dims.actor_sizes, dims.critic_sizes,
             dims.actor_sizes, dims.critic_sizes)
    nets = [_unpack_net(weights[i], biases[i], sz)
            for i, sz in enumerate(sizes)]
    return {
        "actor": nets[0], "critic": nets[1],
        "actor_targ": nets[2], "critic_targ": nets[3],
        "actor_mu": _unpack_net(mom_w[0, 0], mom_b[0, 0], dims.actor_sizes),
        "actor_nu": _unpack_net(mom_w[0, 1], mom_b[0, 1], dims.actor_sizes),
        "critic_mu": _unpack_net(mom_w[1, 0], mom_b[1, 0], dims.critic_sizes),
        "critic_nu": _unpack_net(mom_w[1, 1], mom_b[1, 1], dims.critic_sizes),
        "actor_count": counts[0], "critic_count": counts[1],
    }


def pack_minibatches(batches, dims: PackedDims):
    """Pre-gathered minibatches -> padded kernel inputs.

    ``batches`` is (s, a, r, s2), each ``[..., U, B, dim]``. Returns
    (sx, cx, s2x, r): actor input, critic input (state lanes then action
    lanes) and next-state input, zero-padded to P lanes. Pure concatenation —
    exact, and hoisted out of the update loop entirely.
    """
    s, a, r, s2 = batches
    k, m, p = dims.state_dim, dims.action_dim, dims.pad
    zk = jnp.zeros((*s.shape[:-1], p - k), jnp.float32)
    zc = jnp.zeros((*s.shape[:-1], p - k - m), jnp.float32)
    sx = jnp.concatenate([s, zk], axis=-1)
    s2x = jnp.concatenate([s2, zk], axis=-1)
    cx = jnp.concatenate([s, a, zc], axis=-1)
    return sx, cx, s2x, r


# ---------------------------------------------------------------------------
# The packed update step (shared by the kernel body and the XLA twin)
# ---------------------------------------------------------------------------

def _mlp_fwd(w, b, x):
    """3-layer padded MLP, ReLU trunk, linear head. Zero padding is a fixed
    point of the trunk: relu(0 @ W + 0) = 0 on every padded lane."""
    h = x
    for i in range(NUM_LAYERS - 1):
        h = jax.nn.relu(jnp.dot(h, w[i], preferred_element_type=jnp.float32)
                        + b[i])
    return jnp.dot(h, w[NUM_LAYERS - 1],
                   preferred_element_type=jnp.float32) + b[NUM_LAYERS - 1]


def _actor_fwd(w, b, x, act_mask):
    """sigmoid head, masked to the real action lanes (sigmoid(0) = 0.5 on
    padding would otherwise leak into the critic input and its gradients)."""
    return jax.nn.sigmoid(_mlp_fwd(w, b, x)) * act_mask


def _critic_fwd(w, b, x):
    return _mlp_fwd(w, b, x)[:, 0]


def _adam(count, mu_w, mu_b, nu_w, nu_b, gw, gb, w, b, lr):
    """One ``optim.adam`` step on a packed (w, b) pair — the same op order as
    ``optim.transform.scale_by_adam`` + ``scale(-lr)`` + ``apply_updates``,
    so the packed learner matches ``ddpg_update`` to float32 rounding."""
    count = count + 1
    cf = count.astype(jnp.float32)
    c1 = 1 - _ADAM_B1 ** cf
    c2 = 1 - _ADAM_B2 ** cf
    mu_w = _ADAM_B1 * mu_w + (1 - _ADAM_B1) * gw
    mu_b = _ADAM_B1 * mu_b + (1 - _ADAM_B1) * gb
    nu_w = _ADAM_B2 * nu_w + (1 - _ADAM_B2) * jnp.square(gw)
    nu_b = _ADAM_B2 * nu_b + (1 - _ADAM_B2) * jnp.square(gb)
    w = w + (mu_w / c1) / (jnp.sqrt(nu_w / c2) + _ADAM_EPS) * (-lr)
    b = b + (mu_b / c1) / (jnp.sqrt(nu_b / c2) + _ADAM_EPS) * (-lr)
    return count, mu_w, mu_b, nu_w, nu_b, w, b


def _place_actions(base_x, actions, dims: PackedDims):
    """Write actions into the critic-input action lanes [k, k+m).

    ``base_x`` has exact zeros there, so addition is exact placement."""
    k, m, p = dims.state_dim, dims.action_dim, dims.pad
    rows = actions.shape[0]
    return base_x + jnp.concatenate(
        [jnp.zeros((rows, k), jnp.float32), actions[:, :m],
         jnp.zeros((rows, p - k - m), jnp.float32)], axis=1)


def packed_update(carry, batch, dims: PackedDims, gamma, tau,
                  actor_lr, critic_lr, act_mask):
    """One DDPG update on the packed layout: the float32 arithmetic of
    ``core.ddpg._ddpg_step``, on [P, P]-blocked tensors.

    ``carry`` = (weights [4,L,P,P], biases [4,L,P], mom_w [2,2,L,P,P],
    mom_b [2,2,L,P], counts [2] i32); ``batch`` = (sx, cx, s2x, r) for one
    minibatch. Returns (carry, (critic_loss, actor_loss, q_mean)).
    """
    weights, biases, mom_w, mom_b, counts = carry
    sx, cx, s2x, r = batch

    # --- critic: Bellman regression against the frozen targets -------------
    a2 = _actor_fwd(weights[2], biases[2], s2x, act_mask)
    c2x = _place_actions(s2x, a2, dims)
    q_targ = jax.lax.stop_gradient(
        r + gamma * _critic_fwd(weights[3], biases[3], c2x))

    def critic_loss_fn(wb):
        w, b = wb
        return jnp.mean(jnp.square(_critic_fwd(w, b, cx) - q_targ))

    critic_loss, (gcw, gcb) = jax.value_and_grad(critic_loss_fn)(
        (weights[1], biases[1]))
    (ccnt, cmu_w, cmu_b, cnu_w, cnu_b, cw, cb) = _adam(
        counts[1], mom_w[1, 0], mom_b[1, 0], mom_w[1, 1], mom_b[1, 1],
        gcw, gcb, weights[1], biases[1], critic_lr)

    # --- actor: ascend Q(s, mu(s)) with the updated critic frozen ----------
    def actor_loss_fn(wb):
        w, b = wb
        mu = _actor_fwd(w, b, sx, act_mask)
        return -jnp.mean(_critic_fwd(cw, cb, _place_actions(sx, mu, dims)))

    actor_loss, (gaw, gab) = jax.value_and_grad(actor_loss_fn)(
        (weights[0], biases[0]))
    (acnt, amu_w, amu_b, anu_w, anu_b, aw, ab) = _adam(
        counts[0], mom_w[0, 0], mom_b[0, 0], mom_w[0, 1], mom_b[0, 1],
        gaw, gab, weights[0], biases[0], actor_lr)

    # --- Polyak targets + metrics ------------------------------------------
    atw = (1 - tau) * weights[2] + tau * aw
    atb = (1 - tau) * biases[2] + tau * ab
    ctw = (1 - tau) * weights[3] + tau * cw
    ctb = (1 - tau) * biases[3] + tau * cb
    q_mean = jnp.mean(_critic_fwd(cw, cb, cx))

    carry = (jnp.stack([aw, cw, atw, ctw]), jnp.stack([ab, cb, atb, ctb]),
             jnp.stack([jnp.stack([amu_w, anu_w]),
                        jnp.stack([cmu_w, cnu_w])]),
             jnp.stack([jnp.stack([amu_b, anu_b]),
                        jnp.stack([cmu_b, cnu_b])]),
             jnp.stack([acnt, ccnt]))
    return carry, (critic_loss, actor_loss, q_mean)


# ---------------------------------------------------------------------------
# Pallas kernel: whole inner loop, params resident in VMEM, grid = sessions
# ---------------------------------------------------------------------------

def _ddpg_kernel(dims: PackedDims, gamma, tau, actor_lr, critic_lr,
                 num_updates: int,
                 sx_ref, cx_ref, s2x_ref, r_ref,
                 w_ref, b_ref, mw_ref, mb_ref, cnt_ref,
                 ow_ref, ob_ref, omw_ref, omb_ref, ocnt_ref, met_ref):
    act_mask = (jax.lax.broadcasted_iota(jnp.int32, (1, dims.pad), 1)
                < dims.action_dim).astype(jnp.float32)
    # load once: all four parameter sets + both moment sets stay in VMEM for
    # the whole loop — nothing round-trips between the 96 updates
    params = (w_ref[0], b_ref[0], mw_ref[0], mb_ref[0], cnt_ref[0])
    met0 = jnp.zeros((num_updates, 3), jnp.float32)
    sx, cx, s2x, r = sx_ref[0], cx_ref[0], s2x_ref[0], r_ref[0]

    def body(u, carry):
        params, met = carry
        batch = tuple(jax.lax.dynamic_index_in_dim(t, u, 0, keepdims=False)
                      for t in (sx, cx, s2x, r))
        params, (cl, al, qm) = packed_update(
            params, batch, dims, gamma, tau, actor_lr, critic_lr, act_mask)
        met = jax.lax.dynamic_update_index_in_dim(
            met, jnp.stack([cl, al, qm]), u, 0)
        return params, met

    (weights, biases, mom_w, mom_b, counts), met = jax.lax.fori_loop(
        0, num_updates, body, (params, met0))
    ow_ref[0] = weights
    ob_ref[0] = biases
    omw_ref[0] = mom_w
    omb_ref[0] = mom_b
    ocnt_ref[0] = counts
    met_ref[0] = met


def ddpg_fused_learn(packed, batches, *, dims: PackedDims, gamma: float,
                     tau: float, actor_lr: float, critic_lr: float,
                     interpret: bool = False):
    """Run the full ``num_updates`` inner loop as one Pallas kernel.

    ``packed`` = (weights, biases, mom_w, mom_b, counts) with a leading
    fleet axis N on every array; ``batches`` = ``pack_minibatches`` output,
    each ``[N, U, B, P]`` / ``[N, U, B]``. The grid is (N,): each session's
    learner runs as an independent program instance. Returns (packed',
    metrics dict of [N, U] arrays). Parameter inputs are aliased to the
    outputs — callers must treat ``packed`` as consumed.
    """
    weights, biases, mom_w, mom_b, counts = packed
    sx, cx, s2x, r = batches
    n, u = sx.shape[0], sx.shape[1]
    p = dims.pad

    def bspec(shape):
        nd = len(shape)
        return pl.BlockSpec((1, *shape), lambda i, nd=nd: (i,) + (0,) * nd)

    in_specs = [bspec(sx.shape[1:]), bspec(cx.shape[1:]),
                bspec(s2x.shape[1:]), bspec(r.shape[1:]),
                bspec(weights.shape[1:]), bspec(biases.shape[1:]),
                bspec(mom_w.shape[1:]), bspec(mom_b.shape[1:]),
                bspec(counts.shape[1:])]
    out_specs = [bspec(weights.shape[1:]), bspec(biases.shape[1:]),
                 bspec(mom_w.shape[1:]), bspec(mom_b.shape[1:]),
                 bspec(counts.shape[1:]), bspec((u, 3))]
    out_shape = [jax.ShapeDtypeStruct(weights.shape, jnp.float32),
                 jax.ShapeDtypeStruct(biases.shape, jnp.float32),
                 jax.ShapeDtypeStruct(mom_w.shape, jnp.float32),
                 jax.ShapeDtypeStruct(mom_b.shape, jnp.float32),
                 jax.ShapeDtypeStruct(counts.shape, jnp.int32),
                 jax.ShapeDtypeStruct((n, u, 3), jnp.float32)]
    # rough cost: fwd+bwd over 5 network passes per update (helps scheduling)
    gemm_flops = 2 * sx.shape[2] * p * p * NUM_LAYERS
    cost = pl.CostEstimate(flops=int(n * u * 15 * gemm_flops),
                           bytes_accessed=int(weights.nbytes * 3),
                           transcendentals=int(n * u * sx.shape[2] * p * 2))
    kernel = functools.partial(_ddpg_kernel, dims, gamma, tau, actor_lr,
                               critic_lr, u)
    ow, ob, omw, omb, ocnt, met = pl.pallas_call(
        kernel,
        grid=(n,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        input_output_aliases={4: 0, 5: 1, 6: 2, 7: 3, 8: 4},
        cost_estimate=cost,
        interpret=interpret,
    )(sx, cx, s2x, r, weights, biases, mom_w, mom_b, counts)
    metrics = {"critic_loss": met[..., 0], "actor_loss": met[..., 1],
               "q_mean": met[..., 2]}
    return (ow, ob, omw, omb, ocnt), metrics


# ---------------------------------------------------------------------------
# XLA twin: the same packed update as a lax.scan (fleet-batched GEMM path)
# ---------------------------------------------------------------------------

def ddpg_fused_xla(packed, batches, *, dims: PackedDims, gamma: float,
                   tau: float, actor_lr: float, critic_lr: float):
    """The kernel's computation compiled by XLA: scan over updates, vmapped
    over the fleet axis. Same packed blocks, same float32 op order — used to
    validate the kernel and to benchmark the blocked-GEMM formulation against
    the unpadded scan on CPU/GPU."""
    act_mask = (jnp.arange(dims.pad) < dims.action_dim
                ).astype(jnp.float32)[None, :]

    def one_session(carry, batch):
        def body(c, bt):
            c, (cl, al, qm) = packed_update(
                c, bt, dims, gamma, tau, actor_lr, critic_lr, act_mask)
            return c, jnp.stack([cl, al, qm])
        return jax.lax.scan(body, carry, batch)

    packed, met = jax.vmap(one_session)(packed, batches)
    metrics = {"critic_loss": met[..., 0], "actor_loss": met[..., 1],
               "q_mean": met[..., 2]}
    return packed, metrics
