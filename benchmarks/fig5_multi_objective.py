"""Paper Fig. 5: multi-objective (throughput + IOPS, equal weights) tuning.

Paper averages vs default: +119.4% throughput, +272.8% IOPS.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, run_pair
from repro.envs import WORKLOADS


def run(seeds=(0, 1, 2), steps: int = 30) -> list:
    rows = [csv_row("workload", "method", "throughput_gain_pct",
                    "iops_gain_pct")]
    means = {("magpie", "throughput"): [], ("magpie", "iops"): [],
             ("bestconfig", "throughput"): [], ("bestconfig", "iops"): []}
    for wl in WORKLOADS:
        res = run_pair(wl, {"throughput": 1.0, "iops": 1.0}, steps, seeds)
        for method in ("magpie", "bestconfig"):
            t = res[method]["throughput"]["mean"]
            i = res[method]["iops"]["mean"]
            rows.append(csv_row(wl, method, f"{t*100:.1f}", f"{i*100:.1f}"))
            means[(method, "throughput")].append(t)
            means[(method, "iops")].append(i)
    for method in ("magpie", "bestconfig"):
        rows.append(csv_row(
            "AVERAGE", method,
            f"{np.mean(means[(method, 'throughput')])*100:.1f}",
            f"{np.mean(means[(method, 'iops')])*100:.1f}"))
    rows.append(csv_row("paper_reference", "magpie", "119.4", "272.8"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
