"""Paper Fig. 7: progressive tuning on Video Server — 10-step increments up
to 100; Magpie gains early then fine-tunes; Progressive BestConfig (small
round_size, early recursive bounding) is easily trapped.
"""

from __future__ import annotations

from benchmarks.common import csv_row, make_bestconfig, make_magpie
from repro.envs import LustreSimEnv


def run(seed: int = 0, increments: int = 10, step_size: int = 10) -> list:
    rows = [csv_row("method", "steps", "throughput_gain_pct")]
    weights = {"throughput": 1.0}
    tuner, _ = make_magpie(LustreSimEnv("video_server", seed=seed), weights,
                           seed)
    # Progressive BestConfig: round_size=10 -> DDS+RBS kicks in every 10 steps
    bc, _ = make_bestconfig(LustreSimEnv("video_server", seed=seed + 100),
                            weights, seed, round_size=step_size)
    for i in range(increments):
        r = tuner.run(step_size)
        b = bc.run(step_size)
        steps = (i + 1) * step_size
        rows.append(csv_row("progressive_magpie", steps,
                            f"{r.gain('throughput')*100:.1f}"))
        rows.append(csv_row("progressive_bestconfig", steps,
                            f"{b.gain('throughput')*100:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
