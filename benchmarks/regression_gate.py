"""Benchmark-regression gate: fail CI when throughput leaves the noise band.

The committed BENCH_<n>.json series is the perf trajectory; every full-mode
point carries ``fleet_session_steps_per_sec`` (the canonical 64-session
steady-state number) and a ``noise_band``. This gate re-measures that same
point at FULL fidelity (64 sessions, chunk 16, 5 steps, 96 updates — the
quick smoke parameters are deliberately NOT comparable), compares it against
the latest committed full-mode point with the same ``vs_previous`` machinery
the BENCH writer uses, and exits non-zero only on a ``regression`` label.
``within_noise`` and ``improvement`` pass — the gate enforces the trajectory,
it does not demand monotone speedups from a noisy box.

    PYTHONPATH=src python -m benchmarks.regression_gate            # measure
    PYTHONPATH=src python -m benchmarks.regression_gate --repeats 5

With no committed full-mode BENCH point the gate passes vacuously (a fresh
clone has nothing to regress against).

When the gated ``--bench-json`` point carries a ``shared_experience``
entry (benchmarks/shared_experience.py) or a ``resilience`` entry
(benchmarks/resilience.py), its recorded acceptance — steps-to-gain ratio
and replay bytes/session cut, or off-path identity / on-path overhead /
recovery — is honored too: a point whose acceptance failed exits 1.

Exit-code contract (pinned by tests/test_bench_gate.py):
    0  pass — within noise, improvement, or vacuous (nothing committed)
    1  regression — the measured median left the committed noise band,
       or the point's shared-experience acceptance failed
    2  unusable input — ``--bench-json`` file missing/unreadable, malformed
       or empty JSON, not a JSON object, quick-mode point, or a point
       without ``fleet_session_steps_per_sec``; diagnostics go to stderr
       and the trajectory verdict is NOT rendered (2 never means
       "regressed", it means "could not gate").
"""

from __future__ import annotations

import argparse
import json
import sys
import time

from benchmarks.common import repeat_measure, vs_previous


def evaluate_gate(current: dict, prev_sps: float, prev_file: str) -> dict:
    """Pure gate decision, unit-testable without timing anything.

    ``current`` is a ``repeat_measure``-shaped dict (``median`` +
    ``noise_band``); the gate fails ONLY on the ``regression`` label —
    a median ratio below ``1 - noise_band``."""
    comparison = vs_previous(current, prev_sps, prev_file)
    return {"ok": comparison["label"] != "regression",
            "comparison": comparison}


def measure_steady_state(repeats: int = 3, steps: int = 5,
                         updates: int = 96) -> dict:
    """The canonical trajectory point: 64-session chunked fleet throughput
    at full benchmark fidelity, median over ``repeats`` fresh runs."""
    from benchmarks.fleet_throughput import _scaling_fleet

    fleet = _scaling_fleet(64, chunk=16, updates=updates)
    fleet.precompile(steps)

    def one() -> float:
        t0 = time.perf_counter()
        fleet.run(steps)
        return steps * 64 / (time.perf_counter() - t0)

    return repeat_measure(one, repeats)


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--repeats", type=int, default=3,
                   help="fresh timed runs for the gate measurement")
    p.add_argument("--bench-json", default=None,
                   help="gate a pre-written full-mode BENCH json instead of "
                        "measuring (must carry fleet_session_steps_per_sec)")
    args = p.parse_args(argv)

    from benchmarks.fleet_throughput import _previous_bench

    prev = _previous_bench()
    if prev is None:
        print("regression-gate: no committed full-mode BENCH point; "
              "passing vacuously")
        return 0

    if args.bench_json:
        try:
            with open(args.bench_json) as f:
                point = json.load(f)
        except OSError as e:
            print(f"regression-gate: cannot read {args.bench_json}: {e}",
                  file=sys.stderr)
            return 2
        except json.JSONDecodeError as e:
            print(f"regression-gate: {args.bench_json} is not valid JSON "
                  f"({e})", file=sys.stderr)
            return 2
        if not isinstance(point, dict):
            print(f"regression-gate: {args.bench_json} must hold a JSON "
                  f"object, got {type(point).__name__}", file=sys.stderr)
            return 2
        if point.get("quick"):
            print(f"regression-gate: {args.bench_json} is a quick-mode "
                  "point — not comparable to the committed trajectory",
                  file=sys.stderr)
            return 2
        if "fleet_session_steps_per_sec" not in point:
            print(f"regression-gate: {args.bench_json} carries no "
                  "fleet_session_steps_per_sec — not a full-mode point",
                  file=sys.stderr)
            return 2
        band = point.get("noise_band") or max(
            (pt.get("noise_band", 0.0) for pt in point.get("scaling", [])),
            default=0.0) or 0.14
        current = {"median": point["fleet_session_steps_per_sec"],
                   "noise_band": band}
        se = point.get("shared_experience")
        if se is not None and not se.get("acceptance", {}).get("pass", True):
            acc = se["acceptance"]
            print(f"regression-gate: FAIL — shared-experience point misses "
                  f"its acceptance: steps-to-gain ratio "
                  f"{acc.get('steps_ratio')} (max {acc.get('steps_ratio_max')}"
                  f"), replay bytes/session ratio {acc.get('bytes_ratio')} "
                  f"(min {acc.get('bytes_ratio_min')})", file=sys.stderr)
            return 1
        rz = point.get("resilience")
        if rz is not None and not rz.get("acceptance", {}).get("pass", True):
            acc = rz["acceptance"]
            print(f"regression-gate: FAIL — resilience point misses its "
                  f"acceptance: program_identity="
                  f"{acc.get('program_identity')}, off-path ratio "
                  f"{acc.get('off_path_ratio')} (band "
                  f"{acc.get('off_path_band')}), on-path overhead "
                  f"{acc.get('on_path_overhead')} (max "
                  f"{acc.get('on_path_overhead_max')}), recovered="
                  f"{acc.get('recovered')}, quarantine_ok="
                  f"{acc.get('quarantine_ok')}", file=sys.stderr)
            return 1
    else:
        current = measure_steady_state(repeats=args.repeats)

    verdict = evaluate_gate(current, prev["fleet_session_steps_per_sec"],
                            prev["_file"])
    print(json.dumps(verdict, indent=2, sort_keys=True))
    if not verdict["ok"]:
        print(f"regression-gate: FAIL — "
              f"{verdict['comparison']['median']:.1f} session-steps/s is "
              f"{verdict['comparison']['ratio']:.2f}x the committed "
              f"{verdict['comparison']['previous']:.1f} "
              f"({verdict['comparison']['file']}), outside the "
              f"{verdict['comparison']['noise_band']:.0%} noise band",
              file=sys.stderr)
        return 1
    print(f"regression-gate: ok ({verdict['comparison']['label']})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
