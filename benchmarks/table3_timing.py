"""Paper Table III: per-iteration cost decomposition of the tuning loop.

Paper (on an RTX 5000): action step 3.5 s, model update 0.72 s, one
iteration 4.8 s. Our action step excludes the simulated workload runtime
(the paper's includes a 2-minute Filebench run whose wall time is dominated
by metric retrieval); we report the algorithmic costs + the simulated
restart accounting separately.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, make_magpie
from repro.envs import LustreSimEnv


def run(seed: int = 0, steps: int = 30) -> list:
    env = LustreSimEnv("video_server", seed=seed)
    tuner, _ = make_magpie(env, {"throughput": 1.0}, seed)
    res = tuner.run(steps)
    act = np.mean([h.action_seconds for h in res.history])
    learn = np.mean([h.learn_seconds for h in res.history])
    restart = np.mean([h.restart_seconds for h in res.history])
    rows = [csv_row("name", "seconds", "paper_seconds")]
    rows.append(csv_row("action_step_time", f"{act:.3f}", "3.5 (incl. 2-min run)"))
    rows.append(csv_row("model_update_time", f"{learn:.3f}", "0.72"))
    rows.append(csv_row("one_iteration_time", f"{act+learn:.3f}", "4.8"))
    rows.append(csv_row("simulated_restart_per_step", f"{restart:.1f}",
                        "12-20 (workload restart)"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
