"""Megakernel + async chunk staging benchmark (the BENCH_6 trajectory point).

Two halves of one optimisation story:

* **Device half — the episode megakernel** (``kernels/episode_fused.py``):
  one Pallas program per chunk runs all T env steps — act, env model step,
  reward scalarization, FIFO replay store and the fused learner inner loop —
  with params, Adam moments, the replay window and env state resident across
  the episode. On this CPU box only the interpret/XLA-twin rungs run, so the
  benchmark records the *equivalence* measurement (decision trajectory EXACT,
  float fields' max ulp vs the scan engine) and the roofline VMEM-fit plan,
  not a compiled-TPU throughput number (that is the manual TPU smoke lane's
  job — see .github/workflows/tpu-smoke.yml).

* **Host half — asynchronous chunk staging** (``core.episode.stream_chunks``):
  chunk k+1's host->device ``device_put`` now runs on a dedicated transfer
  thread under chunk k's compute, and chunk k-1's device->host copies are
  enqueued with ``copy_to_host_async`` at dispatch, so the drain decodes
  already-landed bytes. Pure scheduling — bitwise pinned off-vs-on
  (maxulp=0, measured here AND in tests) — so the A/B is wall clock only.

The summary also re-measures the 64-session off-path point (megakernel off,
the default) against the committed ``STEADY_STATE_BAND_64`` trajectory band:
this PR must not tax the path it does not touch.
"""

from __future__ import annotations

import os
import time

import numpy as np

from benchmarks.common import csv_row, repeat_measure, vs_previous
from benchmarks.fleet_throughput import (STEADY_STATE_BAND_64,
                                         _previous_bench, _scaling_fleet,
                                         bench_overlap_ab)

_CACHE: dict = {}


def _max_ulp(a, b) -> int:
    a = np.asarray(a, np.float32)
    b = np.asarray(b, np.float32)
    if a.size == 0:
        return 0
    ai = a.view(np.int32).astype(np.int64)
    bi = b.view(np.int32).astype(np.int64)
    return int(np.max(np.abs(ai - bi)))


def _bitwise_ab_maxulp(steps: int = 4) -> int:
    """Measured max ulp between overlap-off and overlap-on fleet runs
    (expected 0: async staging is pure scheduling; also pinned by
    tests/test_chunked_fleet.py and tests/test_megakernel.py)."""
    from repro.core import DDPGConfig, FleetTuner
    from repro.envs import LustreSimEnv

    cfg = DDPGConfig.for_env(LustreSimEnv("seq_write"), updates_per_step=4)

    def fleet(overlap):
        f = FleetTuner.from_grid(
            ["seq_write"], [{"throughput": 1.0}], [0, 1, 2, 3],
            engine="scan", ddpg_config=cfg, eval_runs=1, warmup_steps=3,
            chunk=2)
        f.overlap = overlap
        return f

    r_on = fleet(True).run(steps)
    r_off = fleet(False).run(steps)
    worst = 0
    for a, b in zip(r_on.results, r_off.results):
        for ha, hb in zip(a.history, b.history):
            assert ha.config == hb.config
            worst = max(worst, _max_ulp(ha.objective, hb.objective))
            worst = max(worst, _max_ulp(ha.reward, hb.reward))
            for k in ha.metrics:
                worst = max(worst, _max_ulp(ha.metrics[k], hb.metrics[k]))
    return worst


def _mega_equivalence(steps: int = 6) -> dict:
    """Scan engine vs megakernel XLA twin through the full Tuner, both under
    REPRO_KERNELS=interpret (the comparable learner path): decision
    trajectory must be EXACT; records the float fields' measured max ulp."""
    from repro.core import DDPGConfig, MagpieAgent, Scalarizer, Tuner
    from repro.envs import LustreSimEnv

    def tuner():
        env = LustreSimEnv("seq_write", seed=3).to_model_env()
        scal = Scalarizer(weights={"throughput": 1.0},
                          specs=env.metric_specs)
        agent = MagpieAgent(DDPGConfig.for_env(env, updates_per_step=6),
                            seed=3, warmup_steps=4)
        return Tuner(env, scal, agent, engine="scan", eval_runs=2)

    saved = {k: os.environ.get(k)
             for k in ("REPRO_KERNELS", "REPRO_MEGAKERNEL")}
    try:
        os.environ["REPRO_KERNELS"] = "interpret"
        os.environ.pop("REPRO_MEGAKERNEL", None)
        base = tuner().run(steps)
        os.environ["REPRO_MEGAKERNEL"] = "xla"
        mega = tuner().run(steps)
    finally:
        for k, v in saved.items():
            if v is None:
                os.environ.pop(k, None)
            else:
                os.environ[k] = v
    worst = 0
    for h, s in zip(base.history, mega.history):
        assert h.config == s.config, (h.config, s.config)
        worst = max(worst, _max_ulp(h.objective, s.objective))
        worst = max(worst, _max_ulp(h.reward, s.reward))
        for k in h.metrics:
            worst = max(worst, _max_ulp(h.metrics[k], s.metrics[k]))
    return {
        "engine": "scan vs megakernel(xla), REPRO_KERNELS=interpret",
        "steps": steps,
        "decisions_exact": base.best_config == mega.best_config,
        "max_ulp": worst,
    }


def _vmem_fragment() -> dict:
    """Roofline fit for the benchmark's fleet shape on a 16 MiB-VMEM core."""
    from repro.core import DDPGConfig
    from repro.envs import LustreSimV2
    from repro.roofline import episode_vmem_plan, suggest_max_capacity

    env = LustreSimV2("seq_write").to_model_env()
    cfg = DDPGConfig.for_env(env, updates_per_step=96)
    capacity = 64  # MagpieAgent's default replay capacity
    kw = dict(steps=5, state_dim=cfg.state_dim, action_dim=cfg.action_dim,
              hidden=cfg.hidden, num_updates=96, batch_size=cfg.batch_size)
    from repro.kernels.ddpg_fused import packed_dims
    pad = packed_dims(cfg.state_dim, cfg.action_dim, cfg.hidden).pad
    plan = episode_vmem_plan(capacity=capacity, pad=pad, **kw)
    return {
        "space": "magpie8",
        "capacity": capacity,
        "pad": pad,
        "per_session_bytes": plan["per_session_bytes"],
        "pipelined_bytes": plan["pipelined_bytes"],
        "budget_bytes": plan["budget_bytes"],
        "fits": plan["fits"],
        "max_capacity_at_budget": suggest_max_capacity(pad=pad, **kw),
    }


def _measure(quick: bool, repeats: int = None) -> dict:
    key = (quick, repeats)
    if key in _CACHE:
        return _CACHE[key]
    if quick:
        _, ab = bench_overlap_ab(256, chunk=8, steps=2, updates=24,
                                 repeats=repeats or 1)
        off64 = _off_path_64(steps=2, updates=24, chunk=8,
                             repeats=repeats or 1)
        equiv = _mega_equivalence(steps=4)
    else:
        # A/B at the sweep's largest size — where synchronous staging cost
        # lived; off-path point at the trajectory band's exact shape
        _, ab = bench_overlap_ab(1024, chunk=16, steps=5, updates=96,
                                 repeats=repeats or 1)
        off64 = _off_path_64(steps=5, updates=96, chunk=16,
                             repeats=repeats or 3)
        equiv = _mega_equivalence(steps=6)
    band = max(ab["on"]["noise_band"], ab["off"]["noise_band"])
    speedup = ab["speedup_on_vs_off"]
    if speedup >= 1.0 + band:
        label = "improvement"
    elif speedup >= 1.0 - band:
        label = "within_noise"
    else:
        label = "regression"
    out = {
        "async_staging_ab": dict(ab, label=label, band=band),
        "bitwise_pin_maxulp": _bitwise_ab_maxulp(),
        "off_path_64": off64,
        "megakernel_equivalence": equiv,
        "vmem_plan": _vmem_fragment(),
    }
    _CACHE[key] = out
    return out


def _off_path_64(steps: int, updates: int, chunk: int, repeats: int) -> dict:
    """The 64-session megakernel-OFF point vs the committed trajectory band
    (full mode matches the band's shape: chunk 16, 5 steps, 96 updates)."""
    fleet = _scaling_fleet(64, chunk, updates)
    fleet.precompile(steps)

    def one():
        t0 = time.perf_counter()
        fleet.run(steps)
        return steps * 64 / (time.perf_counter() - t0)

    meas = repeat_measure(one, repeats)
    lo, hi = STEADY_STATE_BAND_64
    return {
        "session_steps_per_sec": meas["median"],
        "min": meas["min"],
        "noise_band": meas["noise_band"],
        "established_band": [lo, hi],
        # the band floor is what the acceptance is about (no slowdown); a
        # faster-than-band sample on an idle box is fine
        "within_established_band": bool(
            meas["median"] >= lo * (1.0 - meas["noise_band"])),
    }


def run(quick: bool = False, repeats: int = None) -> list:
    m = _measure(quick, repeats)
    ab = m["async_staging_ab"]
    eq = m["megakernel_equivalence"]
    vp = m["vmem_plan"]
    rows = [csv_row("metric", "value", "detail")]
    rows.append(csv_row(
        "async_staging_speedup", f"{ab['speedup_on_vs_off']:.2f}x",
        f"{ab['label']} (band {ab['band']:.3f}, "
        f"{ab['sessions']} sessions chunk {ab['chunk']})"))
    rows.append(csv_row(
        "overlap_efficiency",
        f"{ab['on']['staging'].get('overlap_efficiency', 0.0):.3f}",
        "fraction of staging time hidden under compute"))
    rows.append(csv_row("bitwise_pin_maxulp", m["bitwise_pin_maxulp"],
                        "overlap off vs on (must be 0)"))
    rows.append(csv_row(
        "off_path_64_sps", f"{m['off_path_64']['session_steps_per_sec']:.2f}",
        f"band {m['off_path_64']['established_band']} within="
        f"{m['off_path_64']['within_established_band']}"))
    rows.append(csv_row(
        "megakernel_max_ulp", eq["max_ulp"],
        f"decisions_exact={eq['decisions_exact']} ({eq['engine']})"))
    rows.append(csv_row(
        "vmem_fit", vp["fits"],
        f"magpie8 cap={vp['capacity']}: {vp['pipelined_bytes']} of "
        f"{vp['budget_bytes']} B (max cap {vp['max_capacity_at_budget']})"))
    return rows


def summary(quick: bool = False, repeats: int = None) -> dict:
    m = _measure(quick, repeats)
    ab = m["async_staging_ab"]
    payload = {
        "benchmark": "megakernel",
        "quick": quick,
        "megakernel": {
            "equivalence": m["megakernel_equivalence"],
            "vmem_plan": m["vmem_plan"],
        },
        "async_staging_ab": ab,
        "bitwise_pin_maxulp": m["bitwise_pin_maxulp"],
        "steady_state_64": m["off_path_64"],
        # canonical trajectory key: the 64-session off-path steady state
        "fleet_session_steps_per_sec": (
            m["off_path_64"]["session_steps_per_sec"]),
        "acceptance": {
            "async_ab_label": ab["label"],
            "bitwise_pin_maxulp": m["bitwise_pin_maxulp"],
            "decisions_exact": m["megakernel_equivalence"]["decisions_exact"],
            "pass": bool(
                ab["label"] in ("within_noise", "improvement")
                and m["bitwise_pin_maxulp"] == 0
                and m["megakernel_equivalence"]["decisions_exact"]
                and m["off_path_64"]["within_established_band"]),
        },
    }
    prev = _previous_bench()
    if prev is not None and not quick:
        prev_sps = prev.get("fleet_session_steps_per_sec")
        if prev_sps:
            payload["vs_previous_bench"] = vs_previous(
                {"median": m["off_path_64"]["session_steps_per_sec"],
                 "noise_band": m["off_path_64"]["noise_band"]},
                prev_sps, prev["_file"])
    return payload
