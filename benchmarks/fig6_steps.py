"""Paper Fig. 6: 30 vs 100 tuning steps. Magpie keeps improving with more
steps (it resumes from the 30-step agent state — 'Magpie 100 makes use of the
tuning experience from Magpie 30'); BestConfig mostly does not.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, make_bestconfig, make_magpie
from repro.envs import WORKLOADS, LustreSimEnv


def run(seeds=(0, 1), workloads=None) -> list:
    rows = [csv_row("workload", "method", "steps", "throughput_gain_pct")]
    weights = {"throughput": 1.0}
    for wl in workloads or list(WORKLOADS):
        for seed in seeds:
            tuner, _ = make_magpie(LustreSimEnv(wl, seed=seed), weights, seed)
            r30 = tuner.run(30)          # Magpie 30
            r100 = tuner.run(70)         # +70 on the same agent -> Magpie 100
            bc30, _ = make_bestconfig(LustreSimEnv(wl, seed=seed + 100),
                                      weights, seed)
            b30 = bc30.run(30)
            b100 = bc30.run(70)          # continues its recursive search
            rows.append(csv_row(wl, "magpie", 30, f"{r30.gain('throughput')*100:.1f}"))
            rows.append(csv_row(wl, "magpie", 100, f"{r100.gain('throughput')*100:.1f}"))
            rows.append(csv_row(wl, "bestconfig", 30, f"{b30.gain('throughput')*100:.1f}"))
            rows.append(csv_row(wl, "bestconfig", 100, f"{b100.gain('throughput')*100:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
