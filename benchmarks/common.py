"""Shared benchmark harness utilities (one benchmark per paper table/figure)."""

from __future__ import annotations

import numpy as np

from repro.core import DDPGConfig, MagpieAgent, Scalarizer, Tuner
from repro.core.baselines import BestConfigTuner
from repro.envs import LustreSimEnv


def make_magpie(env, weights, seed: int):
    scal = Scalarizer(weights=weights, specs=env.metric_specs)
    agent = MagpieAgent(DDPGConfig.for_env(env), seed=seed)
    return Tuner(env, scal, agent), scal


def make_bestconfig(env, weights, seed: int, round_size: int = 100):
    scal = Scalarizer(weights=weights, specs=env.metric_specs)
    return BestConfigTuner(env, scal, seed=seed, round_size=round_size), scal


def run_pair(workload: str, weights, steps: int, seeds,
             env_cls=LustreSimEnv) -> dict:
    """Run Magpie + BestConfig over seeds; return mean/sd gains per metric.

    ``env_cls`` picks the space: ``LustreSimEnv`` (the paper's 2-D pair) or
    ``LustreSimV2`` (the 8-knob space) — the tuners size themselves from the
    environment's ``ParamSpace``.
    """
    out = {"magpie": {}, "bestconfig": {}}
    metrics = list(weights)
    acc = {m: {k: [] for k in metrics} for m in out}
    for seed in seeds:
        tuner, _ = make_magpie(env_cls(workload, seed=seed), weights, seed)
        res = tuner.run(steps)
        for k in metrics:
            acc["magpie"][k].append(res.gain(k))
        bc, _ = make_bestconfig(env_cls(workload, seed=seed + 100),
                                weights, seed)
        res_b = bc.run(steps)
        for k in metrics:
            acc["bestconfig"][k].append(res_b.gain(k))
    for method in acc:
        for k in metrics:
            vals = np.asarray(acc[method][k])
            out[method][k] = {"mean": float(vals.mean()),
                              "sd": float(vals.std())}
    return out


def csv_row(*cols) -> str:
    return ",".join(str(c) for c in cols)
