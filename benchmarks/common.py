"""Shared benchmark harness utilities (one benchmark per paper table/figure)."""

from __future__ import annotations

import numpy as np

from repro.core import DDPGConfig, MagpieAgent, Scalarizer, Tuner
from repro.core.baselines import BestConfigTuner
from repro.envs import LustreSimEnv


def make_magpie(env, weights, seed: int):
    scal = Scalarizer(weights=weights, specs=env.metric_specs)
    agent = MagpieAgent(DDPGConfig.for_env(env), seed=seed)
    return Tuner(env, scal, agent), scal


def make_bestconfig(env, weights, seed: int, round_size: int = 100):
    scal = Scalarizer(weights=weights, specs=env.metric_specs)
    return BestConfigTuner(env, scal, seed=seed, round_size=round_size), scal


def run_pair(workload: str, weights, steps: int, seeds,
             env_cls=LustreSimEnv) -> dict:
    """Run Magpie + BestConfig over seeds; return mean/sd gains per metric.

    ``env_cls`` picks the space: ``LustreSimEnv`` (the paper's 2-D pair) or
    ``LustreSimV2`` (the 8-knob space) — the tuners size themselves from the
    environment's ``ParamSpace``.
    """
    out = {"magpie": {}, "bestconfig": {}}
    metrics = list(weights)
    acc = {m: {k: [] for k in metrics} for m in out}
    for seed in seeds:
        tuner, _ = make_magpie(env_cls(workload, seed=seed), weights, seed)
        res = tuner.run(steps)
        for k in metrics:
            acc["magpie"][k].append(res.gain(k))
        bc, _ = make_bestconfig(env_cls(workload, seed=seed + 100),
                                weights, seed)
        res_b = bc.run(steps)
        for k in metrics:
            acc["bestconfig"][k].append(res_b.gain(k))
    for method in acc:
        for k in metrics:
            vals = np.asarray(acc[method][k])
            out[method][k] = {"mean": float(vals.mean()),
                              "sd": float(vals.std())}
    return out


def csv_row(*cols) -> str:
    return ",".join(str(c) for c in cols)


#: The CI box's measured run-to-run throughput spread for the identical
#: engine (BENCH_0's 63.3 vs BENCH_1's 55.1 session-steps/s: ~14% relative).
#: Within-process repeats understate cross-process noise, so noise bands are
#: floored here — a trajectory ratio inside this band is measurement noise,
#: not a perf change (the lesson of BENCH_1's 0.87 "regression").
ESTABLISHED_NOISE_BAND_REL = 0.14


def repeat_measure(fn, repeats: int) -> dict:
    """Run ``fn() -> float`` ``repeats`` times; report median/min/max plus a
    ``noise_band`` (relative spread, floored at the box's established
    cross-run band). Benchmarks record the median and compare trajectories
    against the band instead of against a single noisy sample."""
    samples = [float(fn()) for _ in range(max(1, repeats))]
    med = float(np.median(samples))
    spread = (max(samples) - min(samples)) / med if med else 0.0
    return {
        "median": med,
        "min": float(min(samples)),
        "max": float(max(samples)),
        "samples": samples,
        "noise_band": max(float(spread), ESTABLISHED_NOISE_BAND_REL),
    }


def vs_previous(current: dict, prev_value, file: str) -> dict:
    """Trajectory comparison: current median vs the previous BENCH point,
    labeled against the noise band. ``within_noise`` means the ratio moved
    less than the band — BENCH_1's 0.87 vs BENCH_0 lands here, not in
    ``regression``."""
    ratio = current["median"] / prev_value
    band = current["noise_band"]
    if abs(ratio - 1.0) <= band:
        label = "within_noise"
    else:
        label = "improvement" if ratio > 1.0 else "regression"
    return {
        "file": file,
        "previous": float(prev_value),
        "median": current["median"],
        "ratio": float(ratio),
        "noise_band": band,
        "label": label,
    }
