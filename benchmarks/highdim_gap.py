"""The headline contrast at realistic dimensionality: Magpie vs BestConfig on
the paper's 2-D space and on the 8-knob ``LustreSimV2`` space.

The paper reports +39.7 pp over BestConfig on 2 parameters (Fig. 4). Related
work (DIAL, CARAT) argues production client stacks expose 6-10 interacting
knobs; at 8-D the search box has ~5.5M distinct configurations, DDS sampling
gets one interval per knob per round, and RBS bounds around noisy winners —
while Magpie's metric state still attributes each knob's effect. The gap
(magpie_gain - bestconfig_gain) should therefore WIDEN with dimensionality.

Usage:
    PYTHONPATH=src:. python benchmarks/highdim_gap.py [--quick]
"""

from __future__ import annotations

import argparse

import numpy as np

from benchmarks.common import csv_row, run_pair
from repro.envs import LustreSimEnv, LustreSimV2

WEIGHTS = {"throughput": 1.0}


def run(seeds=(0, 1, 2), steps: int = 30,
        workloads=("seq_write", "video_server", "random_rw")) -> list:
    rows = [csv_row("space", "workload", "magpie_gain_pct",
                    "bestconfig_gain_pct", "gap_pp")]
    gaps = {}
    for name, env_cls in (("paper_2d", LustreSimEnv),
                          ("magpie8_8d", LustreSimV2)):
        gaps[name] = []
        for wl in workloads:
            res = run_pair(wl, WEIGHTS, steps, seeds, env_cls=env_cls)
            m = res["magpie"]["throughput"]["mean"]
            b = res["bestconfig"]["throughput"]["mean"]
            gaps[name].append(m - b)
            rows.append(csv_row(name, wl, f"{m*100:.1f}", f"{b*100:.1f}",
                                f"{(m-b)*100:.1f}"))
        rows.append(csv_row(name, "AVERAGE", "", "",
                            f"{np.mean(gaps[name])*100:.1f}"))
    rows.append(csv_row(
        "gap_widening_pp", "8d_minus_2d", "", "",
        f"{(np.mean(gaps['magpie8_8d']) - np.mean(gaps['paper_2d']))*100:.1f}"))
    return rows


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--quick", action="store_true",
                        help="one seed, fewer steps for CI smoke runs")
    args = parser.parse_args()
    out = (run(seeds=(0,), steps=15, workloads=("seq_write",))
           if args.quick else run())
    print("\n".join(out))
