"""Benchmark harness entry point: one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run            # all (paper set)
    PYTHONPATH=src python -m benchmarks.run --quick    # reduced seeds
    PYTHONPATH=src python -m benchmarks.run --only fig4

The dry-run/roofline table (the per-arch benchmark of this framework) is
produced by `python -m repro.launch.dryrun`; its JSON is summarized here if
present.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _dryrun_summary(path="benchmarks/results/dryrun.json") -> list:
    if not os.path.exists(path):
        return [f"(no dry-run results at {path}; run python -m "
                f"repro.launch.dryrun)"]
    with open(path) as f:
        recs = json.load(f)
    rows = ["arch,shape,mesh,status,mem_gb,compute_s,memory_s,collective_s,"
            "dominant,useful_ratio"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] != "ok":
            rows.append(f"{r['arch']},{r['shape']},{r['mesh']},{r['status']},"
                        ",,,,,")
            continue
        t = r["roofline"]
        rows.append(
            f"{r['arch']},{r['shape']},{r['mesh']},ok,"
            f"{r['memory']['peak_estimate_bytes']/1e9:.2f},"
            f"{t['compute_s']:.4f},{t['memory_s']:.4f},"
            f"{t['collective_s']:.4f},{t['dominant']},"
            f"{r['useful_flops_ratio']:.2f}")
    n_ok = sum(r["status"] == "ok" for r in recs)
    n_skip = sum(r["status"] == "skip" for r in recs)
    n_fail = sum(r["status"] == "fail" for r in recs)
    rows.append(f"summary,,,{n_ok} ok / {n_skip} skip / {n_fail} fail,,,,,,")
    return rows


def _write_bench_json(summary: dict, root: str = None) -> str:
    """Write the perf-trajectory point as BENCH_<n>.json under ``root``
    (default: the repo root).

    ``<n>`` is the next free index *within root*, so successive PRs leave a
    monotone series of summaries (steps/sec, fleet size, speedup vs host
    loop) that can be diffed across history. CI smoke lanes pass
    ``--output-dir`` so their throwaway points number against a scratch
    directory instead of appending to the committed trajectory."""
    root = root or os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    os.makedirs(root, exist_ok=True)
    n = 0
    while os.path.exists(os.path.join(root, f"BENCH_{n}.json")):
        n += 1
    path = os.path.join(root, f"BENCH_{n}.json")
    with open(path, "w") as f:
        json.dump(summary, f, indent=2, sort_keys=True)
        f.write("\n")
    return path


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--quick", action="store_true", help="reduced seeds/steps")
    p.add_argument("--only", default="",
                   help="run a single benchmark by name (see --list)")
    p.add_argument("--list", action="store_true",
                   help="print the available --only targets and exit")
    p.add_argument("--repeats", type=int, default=0,
                   help="timed repetitions per measurement (0 = benchmark "
                   "defaults); medians + noise bands are recorded either way")
    p.add_argument("--no-bench-json", action="store_true",
                   help="skip writing the BENCH_<n>.json trajectory summary")
    p.add_argument("--output-dir", default=None,
                   help="directory for BENCH_<n>.json (default: repo root; "
                        "CI smoke lanes MUST set this so they never clobber "
                        "the committed perf trajectory)")
    args = p.parse_args()
    repeats = args.repeats or None

    seeds = (0,) if args.quick else (0, 1, 2)
    steps = 20 if args.quick else 30

    from benchmarks import (fig4_single_objective, fig5_multi_objective,
                            fig6_steps, fig7_progressive, fleet_throughput,
                            highdim_gap, megakernel, resilience,
                            shared_experience, table3_timing)

    benches = {
        "fig4": ("Fig. 4 — single-objective throughput tuning (30 steps)",
                 lambda: fig4_single_objective.run(seeds=seeds, steps=steps)),
        "fig5": ("Fig. 5 — multi-objective throughput+IOPS tuning",
                 lambda: fig5_multi_objective.run(seeds=seeds, steps=steps)),
        "fig6": ("Fig. 6 — 30 vs 100 tuning steps",
                 lambda: fig6_steps.run(
                     seeds=(0,) if args.quick else (0, 1),
                     workloads=["video_server", "random_rw"] if args.quick
                     else None)),
        "fig7": ("Fig. 7 — progressive tuning on Video Server",
                 lambda: fig7_progressive.run(
                     increments=5 if args.quick else 10)),
        "table3": ("Table III — per-iteration timing",
                   lambda: table3_timing.run(steps=steps)),
        "fleet": ("Fleet tuning — fused learner + vmapped sessions",
                  lambda: fleet_throughput.run(quick=args.quick,
                                               repeats=repeats or 1)),
        "scaling": ("Streaming chunked fleet runtime — 16..1024 sessions, "
                    "O(chunk) device memory",
                    lambda: fleet_throughput.run_scaling(
                        quick=args.quick, repeats=repeats)),
        "shared-experience": (
            "Shared-experience fleet — steps-to-gain + replay bytes/session",
            lambda: shared_experience.run(quick=args.quick)),
        "resilience": (
            "Self-healing runtime — on/off-path cost, recovery, quarantine",
            lambda: resilience.run(quick=args.quick)),
        "megakernel": (
            "Episode megakernel + async chunk staging — equivalence, "
            "VMEM fit, staging A/B",
            lambda: megakernel.run(quick=args.quick, repeats=repeats)),
        "highdim": ("High-dim gap — Magpie vs BestConfig, 2-D vs 8-knob",
                    lambda: highdim_gap.run(
                        seeds=seeds, steps=steps,
                        workloads=("seq_write",) if args.quick
                        else ("seq_write", "video_server", "random_rw"))),
        "dryrun_baseline": (
            "Dry-run / roofline table — paper-faithful BASELINE",
            lambda: _dryrun_summary(
                "benchmarks/results/dryrun_baseline.json")),
        "dryrun": ("Dry-run / roofline table — post-hillclimb (optimized)",
                   _dryrun_summary),
    }
    if args.list:
        for name, (title, _) in benches.items():
            print(f"{name}: {title}")
        return
    if args.only and args.only not in benches:
        print(f"unknown --only target {args.only!r}; available: "
              f"{', '.join(benches)} (see --list)", file=sys.stderr)
        sys.exit(2)
    for name, (title, fn) in benches.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        print(f"\n=== {name}: {title} ===", flush=True)
        for row in fn():
            print(row, flush=True)
        print(f"[{name} done in {time.time()-t0:.1f}s]", flush=True)

    if args.no_bench_json:
        return
    if not args.only or args.only == "scaling":
        # the scaling point is the trajectory summary going forward: it
        # carries the steady-state 64-session throughput plus the memory
        # and compile-reuse measurements of the chunked runtime
        t0 = time.time()
        print("\n=== bench-json: chunked-runtime scaling trajectory point "
              "===", flush=True)
        summary = fleet_throughput.scaling_summary(quick=args.quick,
                                                   repeats=repeats)
        path = _write_bench_json(summary, root=args.output_dir)
        largest = summary["scaling"][-1]
        print(f"wrote {path} "
              f"({largest['sessions']} sessions @ chunk {summary['chunk']}: "
              f"{largest['session_steps_per_sec']:.1f} session-steps/s, "
              f"{largest['peak_device_bytes_per_session']:.0f} peak device "
              f"B/session; monolithic-64 ratio "
              f"{summary['memory_ratio_monolithic64_vs_largest']:.1f}x) "
              f"in {time.time()-t0:.1f}s", flush=True)
    elif args.only == "fleet":
        t0 = time.time()
        print("\n=== bench-json: episode-engine trajectory point ===",
              flush=True)
        summary = fleet_throughput.episode_summary(quick=args.quick)
        path = _write_bench_json(summary, root=args.output_dir)
        print(f"wrote {path} "
              f"(fleet {summary['fleet_size']}: "
              f"{summary['fleet_session_steps_per_sec']:.1f} session-steps/s, "
              f"{summary['speedup_vs_host_loop']:.1f}x host loop) "
              f"in {time.time()-t0:.1f}s", flush=True)
    elif args.only == "shared-experience":
        t0 = time.time()
        print("\n=== bench-json: shared-experience trajectory point ===",
              flush=True)
        summary = shared_experience.summary(quick=args.quick)
        path = _write_bench_json(summary, root=args.output_dir)
        se = summary["shared_experience"]
        print(f"wrote {path} "
              f"(cell {se['cell_size']}: shared steps-to-gain "
              f"{se['acceptance']['steps_ratio']:.2f}x, replay bytes/session "
              f"{se['acceptance']['bytes_ratio']:.1f}x cut) "
              f"in {time.time()-t0:.1f}s", flush=True)
    elif args.only == "resilience":
        t0 = time.time()
        print("\n=== bench-json: resilience trajectory point ===",
              flush=True)
        summary = resilience.summary(quick=args.quick)
        path = _write_bench_json(summary, root=args.output_dir)
        acc = summary["resilience"]["acceptance"]
        print(f"wrote {path} "
              f"(off-path {acc['off_path_ratio']:.3f}x, on-path "
              f"{acc['on_path_overhead']:+.1%}, "
              f"{'PASS' if acc['pass'] else 'FAIL'}) "
              f"in {time.time()-t0:.1f}s", flush=True)
    elif args.only == "megakernel":
        t0 = time.time()
        print("\n=== bench-json: megakernel + async staging trajectory "
              "point ===", flush=True)
        summary = megakernel.summary(quick=args.quick, repeats=repeats)
        path = _write_bench_json(summary, root=args.output_dir)
        acc = summary["acceptance"]
        ab = summary["async_staging_ab"]
        print(f"wrote {path} "
              f"(async staging {ab['speedup_on_vs_off']:.2f}x "
              f"[{acc['async_ab_label']}], bitwise maxulp="
              f"{acc['bitwise_pin_maxulp']}, "
              f"{'PASS' if acc['pass'] else 'FAIL'}) "
              f"in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
