"""Shared-experience fleet benchmark: steps-to-gain and replay bytes/session.

Three fleets of DDPG tuning sessions run on a correlated ``LustreSimV2``
cell (one ``from_grid`` workload x objective cell: same surface, different
seeds), and the benchmark measures how many env steps each needs to reach
the gain — the paper's real cost metric, since every tuning step is
production time spent running an untuned config:

  independent  per-session replay windows, warmup W     (the PR-5 runtime)
  shared       merged cell FIFO, warmup ceil(W/k)       (``shared_replay``)
  shared+avg   merged FIFO + cell parameter averaging   (``avg_every``)

The per-learner seed-data budget is held constant across arms: an
independent learner enters policy mode with W of its own transitions; a
shared learner enters with k*ceil(W/k) >= W merged transitions. The merged
window gathers the same evidence k times sooner — that amortization is the
steps-to-gain claim, not a luckier random search (the warmup plans are the
same per-session plans either way).

Metric: the trailing-``WINDOW`` cell mean of the NOISE-FREE surface score
(``LustreSimV2._score_batch``) of the configs each session actually ran.
Scoring the trajectory on the noise-free surface removes the env's
lognormal measurement noise so "reached the gain" is not a coin flip;
using the trailing mean of *ran* configs (not one-off maxima) makes the
metric reward sustained tuning quality rather than random-probe breadth.
The target is ``TARGET_FRACTION`` of the independent arm's end-of-run
plateau; steps-to-gain is the first step the trailing mean holds the
target; the headline is the median ratio over seed replications, labeled
against the established noise band.

Replay bytes/session: the shared arms provision the merged cell window at
``k*capacity/2`` slots — half the fleet-total slots of the independent
arm — so replay bytes/session drop exactly 2x while the cell still keeps
a k/2-session-step deeper *shared* history than any single independent
window. The numbers are taken from ``memory_plan`` and pinned against the
live buffer allocations (``matches_live``).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import ESTABLISHED_NOISE_BAND_REL, csv_row

WORKLOAD = "file_server"
WEIGHTS = {"throughput": 1.0}
WINDOW = 4                # trailing-mean window (env steps)
UPDATES = 24              # gradient updates per env step
BASE_CAPACITY = 64        # per-session replay slots (independent arm)
INDEPENDENT_WARMUP = 8    # warmup steps, independent arm
AVG_EVERY = 4             # cadence of the parameter-averaging arm
TARGET_FRACTION = 0.9     # of the independent arm's plateau
ACCEPT_STEPS_RATIO = 0.7  # acceptance: shared reaches the gain in <=0.7x
ACCEPT_BYTES_RATIO = 2.0  # acceptance: replay bytes/session cut at cs=4

_LAST: dict = {}


def _sharing_cfgs():
    from repro.core.sharing import SharingConfig

    return {
        "shared_replay": SharingConfig(shared_replay=True),
        "shared_replay_avg": SharingConfig(
            shared_replay=True, avg_every=AVG_EVERY, avg_opt_state=True),
    }


def _fleet(seeds, sharing, warmup: int, capacity: int):
    from repro.core import DDPGConfig
    from repro.core.fleet import FleetTuner
    from repro.envs.lustre_sim import LustreSimV2

    cfg = DDPGConfig.for_env(LustreSimV2(WORKLOAD), updates_per_step=UPDATES)
    return FleetTuner.from_grid(
        [WORKLOAD], [WEIGHTS], list(seeds), env_cls=LustreSimV2,
        engine="scan", ddpg_config=cfg, eval_runs=1, warmup_steps=warmup,
        buffer_capacity=capacity, sharing=sharing)


def _trail_curve(fleet, steps: int) -> np.ndarray:
    """Trailing-``WINDOW`` cell mean of noise-free surface scores of the
    configs the sessions ran; index ``i`` is env step ``i + WINDOW``."""
    from repro.envs.lustre_sim import LustreSimV2

    fleet.run(steps)
    scorer = LustreSimV2(WORKLOAD)
    per = np.stack([scorer._score_batch([r.config for r in h], WEIGHTS)
                    for h in fleet.histories])
    return np.convolve(per.mean(axis=0), np.ones(WINDOW) / WINDOW,
                       mode="valid")


def _steps_to(curve: np.ndarray, target: float, miss: int) -> int:
    hit = np.nonzero(curve >= target)[0]
    return int(hit[0] + WINDOW) if hit.size else miss


def _ratio_stats(samples) -> dict:
    med = float(np.median(samples))
    spread = (max(samples) - min(samples)) / med if med else 0.0
    band = max(float(spread), ESTABLISHED_NOISE_BAND_REL)
    if med <= 1.0 - ESTABLISHED_NOISE_BAND_REL:
        label = "improvement"          # fewer steps to the gain
    elif med >= 1.0 + ESTABLISHED_NOISE_BAND_REL:
        label = "regression"
    else:
        label = "within_noise"
    return {"median": med, "min": float(min(samples)),
            "max": float(max(samples)),
            "samples": [float(s) for s in samples],
            "noise_band": band, "label": label}


def measure(quick: bool = False) -> dict:
    """Run the three arms over seed replications; cached per mode so
    ``run`` and ``summary`` share one measurement."""
    key = "quick" if quick else "full"
    if key in _LAST:
        return _LAST[key]

    cell = 4 if quick else 8
    steps = 24 if quick else 40
    bases = (0, 25) if quick else (0, 25, 50, 75, 100, 125)
    shared_warmup = -(-INDEPENDENT_WARMUP // cell)       # ceil(W/k)
    shared_capacity = cell * BASE_CAPACITY // 2          # 2x bytes cut
    plateau_tail = max(WINDOW, steps // 5)

    arms = {"independent": (None, INDEPENDENT_WARMUP, BASE_CAPACITY)}
    for name, sh in _sharing_cfgs().items():
        arms[name] = (sh, shared_warmup, shared_capacity)

    reps = []
    for base in bases:
        seeds = [base + i for i in range(cell)]
        curves = {name: _trail_curve(_fleet(seeds, sh, warm, cap), steps)
                  for name, (sh, warm, cap) in arms.items()}
        plateau = float(np.mean(curves["independent"][-plateau_tail:]))
        target = TARGET_FRACTION * plateau
        reps.append({
            "base_seed": base,
            "independent_plateau": plateau,
            "target": target,
            "steps_to_gain": {name: _steps_to(curves[name], target,
                                              miss=steps + 1)
                              for name in arms},
        })

    ratios = {}
    for name in arms:
        if name == "independent":
            continue
        ratios[name] = _ratio_stats(
            [r["steps_to_gain"][name] / r["steps_to_gain"]["independent"]
             for r in reps])

    out = {
        "workload": WORKLOAD,
        "weights": WEIGHTS,
        "cell_size": cell,
        "steps": steps,
        "updates_per_step": UPDATES,
        "window": WINDOW,
        "target_fraction": TARGET_FRACTION,
        "independent_warmup": INDEPENDENT_WARMUP,
        "shared_warmup": shared_warmup,
        "independent_capacity": BASE_CAPACITY,
        "shared_merged_capacity": shared_capacity,
        "replications": reps,
        "steps_to_gain_ratio": ratios,
        "replay": replay_bytes_per_session(cell_size=4),
    }
    out["acceptance"] = {
        "steps_ratio_max": ACCEPT_STEPS_RATIO,
        "bytes_ratio_min": ACCEPT_BYTES_RATIO,
        "steps_ratio": ratios["shared_replay"]["median"],
        "bytes_ratio": out["replay"]["bytes_per_session_ratio"],
        "pass": (ratios["shared_replay"]["median"] <= ACCEPT_STEPS_RATIO
                 and (out["replay"]["bytes_per_session_ratio"]
                      >= ACCEPT_BYTES_RATIO)
                 and out["replay"]["matches_live"]),
    }
    _LAST[key] = out
    return out


def replay_bytes_per_session(cell_size: int = 4) -> dict:
    """Replay bytes/session, independent vs merged, from ``memory_plan`` —
    which ``FleetTuner.memory_plan`` pins against the live allocations."""
    from repro.core.sharing import SharingConfig

    ind = _fleet(range(cell_size), None, INDEPENDENT_WARMUP, BASE_CAPACITY)
    shr = _fleet(range(cell_size), SharingConfig(shared_replay=True),
                 -(-INDEPENDENT_WARMUP // cell_size),
                 cell_size * BASE_CAPACITY // 2)
    pi, ps = ind.memory_plan(steps=8), shr.memory_plan(steps=8)
    bi = pi["per_session"]["replay_bytes"]
    bs = ps["per_session"]["replay_bytes"]
    return {
        "cell_size": cell_size,
        "independent_bytes_per_session": int(bi),
        "shared_bytes_per_session": int(bs),
        "bytes_per_session_ratio": float(bi / bs),
        "matches_live": bool(pi["matches_live"] and ps["matches_live"]),
    }


def run(quick: bool = False) -> list:
    m = measure(quick)
    rows = [csv_row("base_seed", "independent_plateau", "target",
                    "stt_independent", "stt_shared", "stt_shared_avg")]
    for r in m["replications"]:
        stt = r["steps_to_gain"]
        rows.append(csv_row(
            r["base_seed"], f"{r['independent_plateau']:.3f}",
            f"{r['target']:.3f}", stt["independent"], stt["shared_replay"],
            stt["shared_replay_avg"]))
    for name, st in m["steps_to_gain_ratio"].items():
        rows.append(f"{name}: median steps-to-gain ratio "
                    f"{st['median']:.2f}x (min {st['min']:.2f} / max "
                    f"{st['max']:.2f}, band {st['noise_band']:.0%}, "
                    f"{st['label']})")
    rep = m["replay"]
    rows.append(f"replay bytes/session at cell {rep['cell_size']}: "
                f"{rep['independent_bytes_per_session']} independent vs "
                f"{rep['shared_bytes_per_session']} merged "
                f"({rep['bytes_per_session_ratio']:.1f}x cut, "
                f"matches_live={rep['matches_live']})")
    acc = m["acceptance"]
    rows.append(f"acceptance: steps ratio {acc['steps_ratio']:.2f} <= "
                f"{acc['steps_ratio_max']} and bytes ratio "
                f"{acc['bytes_ratio']:.1f} >= {acc['bytes_ratio_min']}: "
                f"{'PASS' if acc['pass'] else 'FAIL'}")
    return rows


def summary(quick: bool = False) -> dict:
    """The BENCH_<n>.json payload: the shared-experience point plus, in
    full mode, a re-measured canonical throughput number so the
    benchmark-regression gate can keep walking the trajectory."""
    payload = {
        "bench": "shared_experience",
        "quick": bool(quick),
        "shared_experience": measure(quick),
    }
    if not quick:
        from benchmarks.fleet_throughput import _previous_bench
        from benchmarks.regression_gate import measure_steady_state

        sps = measure_steady_state(repeats=3)
        payload["throughput"] = sps
        payload["fleet_session_steps_per_sec"] = sps["median"]
        payload["noise_band"] = sps["noise_band"]
        prev = _previous_bench()
        if prev is not None:
            from benchmarks.common import vs_previous

            payload["vs_previous"] = vs_previous(
                sps, prev["fleet_session_steps_per_sec"], prev["_file"])
    return payload
