"""Self-healing runtime benchmark (BENCH_5): resilience cost + recovery.

Four measurements over the resilience subsystem (core/resilience.py):

  off_path    a ``resilience=None`` fleet vs the default-constructed fleet.
              The two key (and ARE, by executable identity — checked with
              ``is``) the SAME cached episode program, so the throughput
              ratio is a null measurement whose only spread is box noise.
              Acceptance pins it to 1.00 within the noise band: BENCH_4's
              fleets carried no resilience argument, and this PR's default
              path still runs that exact executable.
  on_path     a ``ResiliencePolicy()`` fleet vs the plain fleet, timed as
              palindromic A/B runs (ordering cancels box drift) at the
              canonical 96-update learn depth. The resilient body adds
              per-step non-finite detection, one learner-state select and
              the health-event byte (the default every-step snapshot
              cadence carries NO learner copy — see
              ``build_resilient_step``); acceptance caps the median
              overhead at ``ACCEPT_ON_PATH_OVERHEAD``, held against the
              off arm's null-measurement band when the box is too noisy
              to resolve 5%.
  recovery    a NaN-poisoned env (``nan_poison`` via ``FaultInjectedModel``)
              under ``snapshot_every`` in ``SNAPSHOT_WINDOWS``: steps from
              the first NONFINITE event back to a zero-event step must be
              <= fault duration + snapshot_every with no degradation — the
              "recovers within the snapshot window or degrades cleanly"
              claim, measured rather than asserted.
  quarantine  survivor session-steps/sec after a permanently dead chunk is
              quarantined through the leave path, vs a clean service built
              from just the survivors. Quarantine must not tax survivors
              beyond the noise band.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import ESTABLISHED_NOISE_BAND_REL, csv_row

WORKLOAD = "seq_write"
WEIGHTS = {"throughput": 1.0}
UPDATES = 24                    # learn depth for the recovery/quarantine arms
PATH_UPDATES = 96               # learn depth for the cost arms: the canonical
                                # steady-state fidelity (what every committed
                                # BENCH point runs); the health layer's fixed
                                # per-step cost is judged against the real
                                # learn, not a toy one
WARMUP = 3                      # warmup steps (random-probe phase)
NAN_START, NAN_DURATION = 4, 2  # poison burst for the recovery arm
SNAPSHOT_WINDOWS = (1, 2, 4)    # snapshot_every sweep
ACCEPT_ON_PATH_OVERHEAD = 0.05  # resilient fleet may cost <= 5%

_LAST: dict = {}


def _fleet(n: int, chunk: int, resilience=None, env_factory=None,
           updates: int = UPDATES):
    from repro.core import DDPGConfig
    from repro.core.fleet import FleetTuner
    from repro.envs import LustreSimEnv

    env = (env_factory(WORKLOAD, 0) if env_factory
           else LustreSimEnv(WORKLOAD))
    cfg = DDPGConfig.for_env(env, updates_per_step=updates)
    return FleetTuner.from_grid(
        [WORKLOAD], [WEIGHTS], list(range(n)),
        env_cls=None if env_factory else LustreSimEnv,
        env_factory=env_factory, engine="scan", ddpg_config=cfg,
        eval_runs=1, warmup_steps=WARMUP, chunk=chunk,
        resilience=resilience)


def program_identity() -> bool:
    """``resilience=None`` keys the SAME cached episode executable as not
    mentioning resilience at all — for the single and the fleet build."""
    import jax
    import jax.numpy as jnp

    from repro.core import DDPGConfig
    from repro.core.ddpg import fleet_init
    from repro.core.episode import _compiled_episode
    from repro.envs import LustreSimEnv

    env = LustreSimEnv(WORKLOAD, seed=0).to_model_env()
    cfg = DDPGConfig.for_env(env, updates_per_step=UPDATES)
    _, (atx, ctx) = fleet_init(jnp.stack([jax.random.PRNGKey(0)]), cfg)
    same = True
    for fleet in (False, True):
        default = _compiled_episode(env.model.step_fn, env.param_space, cfg,
                                    atx, ctx, True, cfg.updates_per_step,
                                    fleet=fleet, devices=None)
        explicit = _compiled_episode(env.model.step_fn, env.param_space, cfg,
                                     atx, ctx, True, cfg.updates_per_step,
                                     fleet=fleet, devices=None,
                                     resilience=None)
        same = same and (default is explicit)
    return same


def _ratio_stats(samples, center: float = 1.0) -> dict:
    med = float(np.median(samples))
    spread = (max(samples) - min(samples)) / med if med else 0.0
    band = max(float(spread), ESTABLISHED_NOISE_BAND_REL)
    return {"median": med, "min": float(min(samples)),
            "max": float(max(samples)),
            "samples": [float(s) for s in samples],
            "noise_band": band,
            "within_noise": bool(abs(med - center) <= band)}


def measure_paths(quick: bool = False) -> dict:
    """Paired A/B timing: plain vs resilience=None (off path, a null
    measurement) and plain vs ResiliencePolicy() (on path, the real cost).
    Every repeat times all three fleets back to back so slow drift in the
    box cancels out of the per-repeat ratios."""
    from repro.core import ResiliencePolicy

    n, chunk = (8, 4) if quick else (32, 8)
    steps = 8 if quick else 6
    repeats = 5 if quick else 7

    plain = _fleet(n, chunk, updates=PATH_UPDATES)
    off = _fleet(n, chunk, resilience=None,       # same executable as plain
                 updates=PATH_UPDATES)
    on = _fleet(n, chunk, resilience=ResiliencePolicy(),
                updates=PATH_UPDATES)
    for f in (plain, off, on):                    # compile + steady-state
        f.run(steps)

    def one(fleet) -> float:
        t0 = time.perf_counter()
        fleet.run(steps)
        return time.perf_counter() - t0

    off_ratios, on_overheads, sps = [], [], []
    for _ in range(repeats):
        # palindromic A/B ordering: linear drift (thermal, background
        # load) cancels out of the summed-pair ratios
        t_p1, t_o1, t_r1 = one(plain), one(off), one(on)
        t_r2, t_o2, t_p2 = one(on), one(off), one(plain)
        off_ratios.append((t_p1 + t_p2) / (t_o1 + t_o2))
        on_overheads.append((t_r1 + t_r2) / (t_p1 + t_p2) - 1.0)
        sps.append(2 * steps * n / (t_p1 + t_p2))

    off_stats = _ratio_stats(off_ratios)
    over = float(np.median(on_overheads))
    # the off arm is a NULL experiment (same executable on both sides), so
    # its band is the box's same-program A/B noise floor: an on-path
    # overhead below that floor is unresolvable, and the acceptance holds
    # the 5% target against it (the same philosophy as the regression
    # gate's ESTABLISHED_NOISE_BAND_REL)
    return {
        "fleet_size": n,
        "chunk": chunk,
        "steps": steps,
        "updates_per_step": PATH_UPDATES,
        "repeats": repeats,
        "plain_session_steps_per_sec": float(np.median(sps)),
        "off_path_ratio": off_stats,
        "on_path_overhead": {
            "median": over,
            "min": float(min(on_overheads)),
            "max": float(max(on_overheads)),
            "samples": [float(s) for s in on_overheads],
            "max_allowed": ACCEPT_ON_PATH_OVERHEAD,
            "noise_floor": off_stats["noise_band"],
            "ok": bool(over <= max(ACCEPT_ON_PATH_OVERHEAD,
                                   off_stats["noise_band"])),
        },
    }


def measure_recovery(quick: bool = False) -> list:
    """Steps-to-recover after a NaN burst, per snapshot window: first
    zero-event step minus first NONFINITE step, bounded by
    duration + snapshot_every unless the session degraded cleanly."""
    from repro.core import (MagpieAgent, DDPGConfig, ResiliencePolicy,
                            Scalarizer, Tuner)
    from repro.core.resilience import EVENT_DEGRADED, EVENT_NONFINITE
    from repro.envs import (FaultInjectedModel, LustreSimV2, ModelEnv,
                            nan_poison)

    windows = SNAPSHOT_WINDOWS[:2] if quick else SNAPSHOT_WINDOWS
    steps = NAN_START + NAN_DURATION + max(windows) + 4
    rows = []
    for snap in windows:
        base = LustreSimV2(WORKLOAD, seed=0).as_model()
        env = ModelEnv(FaultInjectedModel(
            base, [nan_poison("throughput", start=NAN_START,
                              duration=NAN_DURATION)]), seed=0)
        scal = Scalarizer(weights=WEIGHTS, specs=env.metric_specs)
        agent = MagpieAgent(DDPGConfig.for_env(env, updates_per_step=UPDATES),
                            seed=0, warmup_steps=WARMUP)
        t = Tuner(env, scal, agent, engine="scan", eval_runs=1,
                  resilience=ResiliencePolicy(max_resets=8,
                                              snapshot_every=snap))
        res = t.run(steps)
        ev = np.asarray(t.health_events)
        bad = np.nonzero(ev & EVENT_NONFINITE)[0]
        first_bad = int(bad[0]) if bad.size else None
        clean = (np.nonzero(ev[first_bad:] == 0)[0] + first_bad
                 if first_bad is not None else np.array([], int))
        recover = (int(clean[0]) - first_bad if clean.size else None)
        degraded = bool(res.health_stats["degraded"])
        rows.append({
            "snapshot_every": snap,
            "first_nonfinite_step": first_bad,
            "steps_to_recover": recover,
            "bound": NAN_DURATION + snap,
            "degraded": degraded,
            "resets": int(res.health_stats["resets_total"]),
            "ok": bool(degraded and not np.any(ev[-1] & EVENT_NONFINITE)
                       or (recover is not None
                           and recover <= NAN_DURATION + snap
                           and not np.any(ev & EVENT_DEGRADED))),
        })
    return rows


def measure_quarantine(quick: bool = False) -> dict:
    """Survivor throughput after quarantine: a 4-session service whose
    second chunk dies permanently vs a clean 2-session service — the
    survivors, post-quarantine, should pay nothing beyond noise."""
    from repro.core import ChunkSupervisor, FleetService
    from repro.envs import ChaosConfig

    steps = 3 if quick else 5
    repeats = 2 if quick else 3

    chaos = ChaosConfig(fail_chunks=((1, 99),))   # chunk 1 never stages
    sup = ChunkSupervisor(max_retries=1, backoff_seconds=0.0)
    chaotic = FleetService(chunk=2, warmup_steps=WARMUP, eval_runs=1,
                           supervisor=sup, chaos=chaos.host())
    for seed in range(4):
        chaotic.request_join(WORKLOAD, WEIGHTS, seed)
    clean = FleetService(chunk=2, warmup_steps=WARMUP, eval_runs=1)
    for seed in range(2):
        clean.request_join(WORKLOAD, WEIGHTS, seed)

    chaotic.advance(steps)                        # compile + quarantine
    quarantined = list(chaotic.last_stats.get("quarantined", []))
    chaotic.advance(0)                            # departures take effect
    clean.advance(steps)

    def sps(svc) -> float:
        t0 = time.perf_counter()
        advanced = svc.advance(steps)
        return steps * len(advanced) / (time.perf_counter() - t0)

    ratios = [sps(chaotic) / sps(clean) for _ in range(repeats)]
    stats = _ratio_stats(ratios)
    return {
        "quarantined_sessions": len(quarantined),
        "survivors": 4 - len(quarantined),
        "steps_per_round": steps,
        "survivor_throughput_ratio": stats,
        "ok": bool(len(quarantined) == 2
                   and stats["median"] >= 1.0 - stats["noise_band"]),
    }


def measure(quick: bool = False) -> dict:
    """Run the four arms; cached per mode so ``run`` and ``summary`` share
    one measurement."""
    key = "quick" if quick else "full"
    if key in _LAST:
        return _LAST[key]

    identity = program_identity()
    paths = measure_paths(quick)
    recovery = measure_recovery(quick)
    quarantine = measure_quarantine(quick)

    off = paths["off_path_ratio"]
    over = paths["on_path_overhead"]["median"]
    out = {
        "workload": WORKLOAD,
        "weights": WEIGHTS,
        "updates_per_step": UPDATES,
        "program_identity": identity,
        "paths": paths,
        "recovery": recovery,
        "quarantine": quarantine,
    }
    out["acceptance"] = {
        "program_identity": identity,
        "off_path_ratio": off["median"],
        "off_path_band": off["noise_band"],
        "on_path_overhead": over,
        "on_path_overhead_max": ACCEPT_ON_PATH_OVERHEAD,
        "on_path_noise_floor": paths["on_path_overhead"]["noise_floor"],
        "recovered": all(r["ok"] for r in recovery),
        "quarantine_ok": quarantine["ok"],
        "pass": bool(identity
                     and off["within_noise"]
                     and paths["on_path_overhead"]["ok"]
                     and all(r["ok"] for r in recovery)
                     and quarantine["ok"]),
    }
    _LAST[key] = out
    return out


def run(quick: bool = False) -> list:
    m = measure(quick)
    p = m["paths"]
    rows = [csv_row("arm", "value", "band_or_bound", "verdict")]
    rows.append(csv_row(
        "program_identity", m["program_identity"], "is-comparison",
        "PASS" if m["program_identity"] else "FAIL"))
    off = p["off_path_ratio"]
    rows.append(csv_row(
        "off_path_ratio", f"{off['median']:.3f}",
        f"±{off['noise_band']:.0%}",
        "within_noise" if off["within_noise"] else "DRIFT"))
    over = p["on_path_overhead"]
    rows.append(csv_row(
        "on_path_overhead", f"{over['median']:+.1%}",
        f"<= max({over['max_allowed']:.0%}, floor {over['noise_floor']:.0%})",
        "PASS" if over["ok"] else "FAIL"))
    for r in m["recovery"]:
        rows.append(csv_row(
            f"recovery_snap{r['snapshot_every']}",
            f"{r['steps_to_recover']} steps",
            f"<= {r['bound']}",
            "PASS" if r["ok"] else "FAIL"))
    q = m["quarantine"]
    rows.append(csv_row(
        "survivor_throughput",
        f"{q['survivor_throughput_ratio']['median']:.2f}x",
        f"{q['quarantined_sessions']} quarantined",
        "PASS" if q["ok"] else "FAIL"))
    acc = m["acceptance"]
    rows.append(
        f"acceptance: off-path {acc['off_path_ratio']:.3f} within "
        f"{acc['off_path_band']:.0%}, on-path {acc['on_path_overhead']:+.1%}"
        f" <= max({acc['on_path_overhead_max']:.0%}, "
        f"{acc['on_path_noise_floor']:.0%} floor), recovery+quarantine "
        f"{'ok' if acc['recovered'] and acc['quarantine_ok'] else 'BROKEN'}:"
        f" {'PASS' if acc['pass'] else 'FAIL'}")
    return rows


def summary(quick: bool = False) -> dict:
    """The BENCH_<n>.json payload: the resilience point plus, in full mode,
    a re-measured canonical throughput number so the benchmark-regression
    gate can keep walking the trajectory."""
    payload = {
        "bench": "resilience",
        "quick": bool(quick),
        "resilience": measure(quick),
    }
    if not quick:
        from benchmarks.fleet_throughput import _previous_bench
        from benchmarks.regression_gate import measure_steady_state

        sps = measure_steady_state(repeats=3)
        payload["throughput"] = sps
        payload["fleet_session_steps_per_sec"] = sps["median"]
        payload["noise_band"] = sps["noise_band"]
        prev = _previous_bench()
        if prev is not None:
            from benchmarks.common import vs_previous

            payload["vs_previous"] = vs_previous(
                sps, prev["fleet_session_steps_per_sec"], prev["_file"])
    return payload
