"""Paper Fig. 4: single-objective (throughput) tuning on the 5 Filebench
workloads, 30 tuning steps, Magpie vs BestConfig vs default.

Paper numbers: Magpie avg +91.8% over default, +39.7 pp over BestConfig;
Sequential Write +250.4%.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, run_pair
from repro.envs import WORKLOADS


def run(seeds=(0, 1, 2), steps: int = 30) -> list:
    rows = [csv_row("workload", "method", "throughput_gain_pct", "sd_pct")]
    means = {"magpie": [], "bestconfig": []}
    for wl in WORKLOADS:
        res = run_pair(wl, {"throughput": 1.0}, steps, seeds)
        for method in ("magpie", "bestconfig"):
            g = res[method]["throughput"]
            rows.append(csv_row(wl, method, f"{g['mean']*100:.1f}",
                                f"{g['sd']*100:.1f}"))
            means[method].append(g["mean"])
    for method in ("magpie", "bestconfig"):
        rows.append(csv_row("AVERAGE", method,
                            f"{np.mean(means[method])*100:.1f}", ""))
    rows.append(csv_row("paper_reference", "magpie", "91.8", ""))
    rows.append(csv_row("paper_reference", "magpie_seq_write", "250.4", ""))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))
