"""Fleet-tuning performance: fused scan learner + vmapped multi-session fleet.

Measurements backing the fleet subsystem's perf claims:

  1. ``learn()`` path — per-environment-step model-update time for the legacy
     path (``updates_per_step`` separate jitted dispatches + a host round-trip
     per minibatch sample) vs the fused path (on-device sampling + one
     ``lax.scan`` dispatch). The paper's Table III reports 0.72 s per model
     update on an RTX 5000; the fused path collapses the dispatch overhead
     that dominates at this model size.
  2. Dimensionality — fused learn step on the paper's 2-D space vs the
     8-knob ``LustreSimV2`` space (must stay within ~1.2x: the step is
     dispatch-dominated, so higher-dimensional spaces cost tuning steps,
     not per-step wall clock).
  3. Fleet scaling — wall time per tuning step for N concurrent sessions
     (vmapped learner + vectorized response surface) vs N sequential
     single-session tuners.
  4. Learner formulations at fleet scale (``bench_learner_paths``) — the
     pre-PR per-update-gather scan vs the pre-gathered scan (the default)
     vs the packed blocked-GEMM XLA twin of the Pallas kernel
     (``kernels/ddpg_fused.py``). This is the data behind the dispatch
     default: on CPU the [P, P]-padded GEMMs lose to the unpadded scan, so
     the packed formulation runs only as the TPU kernel's shape.
  5. Streaming chunked runtime scaling (``bench_scaling``) — 16 -> 1024
     sessions through one fixed-size chunk executable: session-steps/s
     (median over ``--repeats``, with noise bands), end-to-end wall clock,
     MEASURED peak resident device bytes per session, compile-reuse
     accounting across >= 2 grid shapes, and the monolithic (chunk=None)
     64-session control. Feeds the ``fleet_scaling`` BENCH_<n>.json point.
  6. Overlap A/B (``bench_overlap_ab``) — the double-buffered chunk staging
     pipeline off vs on at the largest sweep size. Outputs are bitwise
     identical either way; this isolates the wall-clock win from hiding
     host<->device staging under compute.
  7. Service mode (``bench_service``) — ``advance()`` rounds on a standing
     ``FleetService`` (leased chunk slots, per-session host state) vs the
     batch ``FleetTuner`` numbers, quantifying the serving-loop overhead.

Usage:
    PYTHONPATH=src python benchmarks/fleet_throughput.py [--quick]
    PYTHONPATH=src python benchmarks/fleet_throughput.py --scaling [--quick]
    PYTHONPATH=src python benchmarks/fleet_throughput.py --service [--quick]
    PYTHONPATH=src python benchmarks/fleet_throughput.py --overlap-ab [--quick]
"""

from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, repeat_measure, vs_previous
from repro.core import DDPGConfig, FleetTuner, MagpieAgent, Scalarizer, Tuner
from repro.core.ddpg import (_ddpg_step, fleet_init, fleet_learn_scan,
                             gather_minibatches, sample_minibatch_indices)
from repro.envs import LustreSimEnv, LustreSimV2
from repro.kernels import ddpg_fused as _fused
from repro.kernels import ops as _kops


def _fill_buffer(agent: MagpieAgent, n: int, rng: np.random.Generator) -> None:
    k, m = agent.cfg.state_dim, agent.cfg.action_dim
    for _ in range(n):
        agent.observe(rng.random(k).astype(np.float32),
                      rng.random(m).astype(np.float32),
                      float(rng.standard_normal() * 0.1),
                      rng.random(k).astype(np.float32))


def bench_learn_paths(env_steps: int, updates: int) -> list:
    """Per-step learn() time: legacy dispatch loop vs fused scan."""
    env = LustreSimEnv("seq_write", seed=0)
    cfg = DDPGConfig(state_dim=env.state_dim, action_dim=env.action_dim,
                     updates_per_step=updates)
    rng = np.random.default_rng(0)
    rows = [csv_row("path", "per_step_seconds", "dispatches_per_step",
                    "speedup_vs_legacy")]

    times = {}
    for fused in (False, True):
        agent = MagpieAgent(cfg, seed=0)
        _fill_buffer(agent, 32, np.random.default_rng(1))
        agent.learn(fused=fused)  # warm up compilation outside the timer
        t0 = time.perf_counter()
        for _ in range(env_steps):
            _fill_buffer(agent, 1, rng)
            agent.learn(fused=fused)
        times[fused] = (time.perf_counter() - t0) / env_steps

    rows.append(csv_row("legacy_per_update_dispatch", f"{times[False]:.4f}",
                        updates, "1.0"))
    rows.append(csv_row("fused_learn_scan", f"{times[True]:.4f}", 1,
                        f"{times[False] / times[True]:.1f}"))
    return rows


def bench_dimensionality(env_steps: int, updates: int) -> list:
    """Fused learn step cost: paper 2-D space vs the 8-knob V2 space.

    The learner is sized from each space via ``DDPGConfig.for_env`` (same
    hidden trunk, wider action head at 8-D). The fused ``lax.scan`` step is
    dispatch-dominated at this model size, so growing the space 2-D -> 8-D
    must stay within ~1.2x per-step time — dimensionality costs tuning steps
    (sample complexity), not wall clock per step.
    """
    rng = np.random.default_rng(0)
    rows = [csv_row("space", "action_dim", "per_step_seconds",
                    "ratio_vs_2d")]
    times = {}
    for name, env in (("paper_2d", LustreSimEnv("seq_write", seed=0)),
                      ("magpie8_8d", LustreSimV2("seq_write", seed=0))):
        cfg = DDPGConfig.for_env(env, updates_per_step=updates)
        agent = MagpieAgent(cfg, seed=0)
        _fill_buffer(agent, 32, np.random.default_rng(1))
        agent.learn()  # warm up compilation outside the timer
        t0 = time.perf_counter()
        for _ in range(env_steps):
            _fill_buffer(agent, 1, rng)
            agent.learn()
        times[name] = (time.perf_counter() - t0) / env_steps
        rows.append(csv_row(
            name, cfg.action_dim, f"{times[name]:.4f}",
            f"{times[name] / times['paper_2d']:.2f}"))
    return rows


def bench_fleet_scaling(fleet_sizes: list, steps: int) -> list:
    """Fleet step time vs equivalent sequential single-session tuning."""
    rows = [csv_row("sessions", "fleet_seconds_per_step",
                    "sequential_seconds_per_step", "speedup")]
    for n in fleet_sizes:
        seeds = list(range(n))
        fleet = FleetTuner.from_grid(["seq_write"], [{"throughput": 1.0}],
                                     seeds, eval_runs=1)
        fleet.run(1)  # warm up compilation for this fleet width
        t0 = time.perf_counter()
        fleet.run(steps)
        fleet_t = (time.perf_counter() - t0) / steps

        tuners = []
        for seed in seeds:
            env = LustreSimEnv("seq_write", seed=seed)
            scal = Scalarizer(weights={"throughput": 1.0},
                              specs=env.metric_specs)
            agent = MagpieAgent(DDPGConfig(state_dim=env.state_dim,
                                           action_dim=env.action_dim),
                                seed=seed)
            tuners.append(Tuner(env, scal, agent, eval_runs=1))
        for t in tuners:
            t.run(1)  # warm up
        t0 = time.perf_counter()
        for t in tuners:
            t.run(steps)
        seq_t = (time.perf_counter() - t0) / steps

        rows.append(csv_row(n, f"{fleet_t:.4f}", f"{seq_t:.4f}",
                            f"{seq_t / fleet_t:.1f}"))
    return rows


def bench_learner_paths(fleet_size: int, updates: int, reps: int = 5) -> list:
    """Learner formulations, one env step's worth of updates at fleet scale.

    Times ONE ``updates``-deep inner loop for ``fleet_size`` concurrent
    sessions (the per-step learner cost of the fused episode engine) under
    three formulations of the same math:

      scan_pergather   the pre-PR path: one buffer gather per update inside
                       the scan body
      scan_pregather   the default: all ``updates x batch`` rows gathered in
                       one take, scan over ready batches (bitwise-identical
                       states — tests/test_ddpg_fused.py)
      packed_gemm_xla  the Pallas kernel's [P, P]-blocked layout compiled by
                       XLA (``kernels.ops.ddpg_inner_loop`` fallback)

    Throughput is session-steps/s: fleet_size / seconds-per-inner-loop.
    """
    cfg = DDPGConfig(state_dim=12, action_dim=2, updates_per_step=updates)
    keys = jnp.stack([jax.random.PRNGKey(s) for s in range(fleet_size)])
    states, (atx, ctx) = fleet_init(keys, cfg)
    rng = np.random.default_rng(0)
    cap = 64
    data = (jnp.asarray(rng.random((fleet_size, cap, 12)), jnp.float32),
            jnp.asarray(rng.random((fleet_size, cap, 2)), jnp.float32),
            jnp.asarray(rng.standard_normal((fleet_size, cap)), jnp.float32),
            jnp.asarray(rng.random((fleet_size, cap, 12)), jnp.float32))
    sizes = jnp.full((fleet_size,), cap, jnp.int32)
    lkeys = jnp.stack([jax.random.PRNGKey(s + 3) for s in range(fleet_size)])

    @functools.partial(jax.jit, static_argnames=("nu",))
    def pergather(states, data, sizes, keys, nu):
        def one(state, d, size, key):
            idx = sample_minibatch_indices(key, nu, cfg.batch_size, size)
            s, a, r, s2 = d

            def body(st, ix):
                return _ddpg_step(st, (s[ix], a[ix], r[ix], s2[ix]),
                                  cfg, atx, ctx)

            return jax.lax.scan(body, state, idx)

        return jax.vmap(one)(states, data, sizes, keys)

    dims = _fused.packed_dims(cfg.state_dim, cfg.action_dim, cfg.hidden)

    @functools.partial(jax.jit, static_argnames=("nu",))
    def packed_gemm(states, data, sizes, keys, nu):
        def pack_one(state, d, size, key):
            idx = sample_minibatch_indices(key, nu, cfg.batch_size, size)
            batches = gather_minibatches(d, idx)
            a_adam, c_adam = state.actor_opt[0], state.critic_opt[0]
            packed = _fused.pack_params(
                state.actor, state.critic, state.actor_targ,
                state.critic_targ, a_adam.mu, a_adam.nu, c_adam.mu,
                c_adam.nu, a_adam.count, c_adam.count, dims)
            return packed, _fused.pack_minibatches(batches, dims)

        packed, kb = jax.vmap(pack_one)(states, data, sizes, keys)
        return _kops.ddpg_inner_loop(
            packed, kb, dims=dims, gamma=cfg.gamma, tau=cfg.tau,
            actor_lr=cfg.actor_lr, critic_lr=cfg.critic_lr, mode="xla")

    def timed(fn):
        out = fn()
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(reps):
            out = fn()
            jax.block_until_ready(out)
        return (time.perf_counter() - t0) / reps

    t_old = timed(lambda: pergather(states, data, sizes, lkeys, updates))
    t_new = timed(lambda: fleet_learn_scan(states, data, sizes, lkeys, cfg,
                                           atx, ctx, updates))
    t_pk = timed(lambda: packed_gemm(states, data, sizes, lkeys, updates))

    rows = [csv_row("learner_path", "sessions", "inner_loop_seconds",
                    "session_steps_per_sec", "speedup_vs_pergather")]
    for name, t in (("scan_pergather", t_old), ("scan_pregather", t_new),
                    ("packed_gemm_xla", t_pk)):
        rows.append(csv_row(name, fleet_size, f"{t:.4f}",
                            f"{fleet_size / t:.2f}", f"{t_old / t:.2f}"))
    return rows


class _LegacyAgent(MagpieAgent):
    """The step-by-step host learner: ``updates_per_step`` separate jitted
    dispatches + a host minibatch sample per update — the paper's Table III
    per-iteration architecture, and the reference 'host loop' the episode
    engine is measured against."""

    def learn(self, updates=None):
        return super().learn(updates=updates, fused=False)


def _scan_tuner(workload: str, seed: int, updates: int, engine: str,
                legacy: bool = False) -> Tuner:
    env = LustreSimEnv(workload, seed=seed).to_model_env()
    scal = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)
    agent_cls = _LegacyAgent if legacy else MagpieAgent
    agent = agent_cls(DDPGConfig.for_env(env, updates_per_step=updates),
                      seed=seed)
    return Tuner(env, scal, agent, eval_runs=1, engine=engine)


def bench_episode_engine(fleet_sizes: list, steps: int,
                         updates: int = 96, repeats: int = 1) -> tuple:
    """Whole-episode engine vs the host loop, on the same pure env model.

    Three rungs, same algorithm and budget on every one:

      host_loop        the step-by-step Fig. 1 loop with per-minibatch learner
                       dispatches (Table III's architecture) — the baseline
      host_loop_fused  the loop with the PR-1 fused ``ddpg_learn_scan``
                       (one learn dispatch per step, still one host round
                       trip per act/env/learn)
      episode_scan /   this PR: the whole episode (act → env → reward →
      fleet_scan       store → learn) as ONE XLA program, then N sessions
                       vmapped on a fleet axis

    Throughput is session-steps/second; ``speedup_vs_host`` is against
    ``host_loop``. Each configuration is warmed at the measured step count so
    compilation never lands in the timer. Returns (csv rows, summary dict) —
    the summary feeds the repo-root BENCH_<n>.json trajectory file.
    """
    rows = [csv_row("engine", "sessions", "session_steps_per_sec",
                    "speedup_vs_host")]

    def timed(tuner):
        tuner.run(steps)  # warm compilation at this episode length
        t0 = time.perf_counter()
        tuner.run(steps)
        return steps / (time.perf_counter() - t0)

    host_sps = timed(_scan_tuner("seq_write", 0, updates, "host", legacy=True))
    rows.append(csv_row("host_loop", 1, f"{host_sps:.2f}", "1.0"))

    fused_sps = timed(_scan_tuner("seq_write", 0, updates, "host"))
    rows.append(csv_row("host_loop_fused", 1, f"{fused_sps:.2f}",
                        f"{fused_sps / host_sps:.1f}"))

    scan_sps = timed(_scan_tuner("seq_write", 0, updates, "scan"))
    rows.append(csv_row("episode_scan", 1, f"{scan_sps:.2f}",
                        f"{scan_sps / host_sps:.1f}"))

    summary = {"host_loop_steps_per_sec": host_sps,
               "host_loop_fused_steps_per_sec": fused_sps,
               "single_scan_steps_per_sec": scan_sps, "fleets": []}
    for n in fleet_sizes:
        cfg = DDPGConfig.for_env(LustreSimEnv("seq_write"),
                                 updates_per_step=updates)
        fleet = FleetTuner.from_grid(
            ["seq_write"], [{"throughput": 1.0}], list(range(n)),
            engine="scan", ddpg_config=cfg, eval_runs=1)
        fleet.run(steps)  # warm up compilation at this fleet width

        def one():
            t0 = time.perf_counter()
            fleet.run(steps)
            return steps * n / (time.perf_counter() - t0)

        stats = repeat_measure(one, repeats)
        sps = stats["median"]
        rows.append(csv_row("fleet_scan", n, f"{sps:.2f}",
                            f"{sps / host_sps:.1f}"))
        summary["fleets"].append({
            "sessions": n, "session_steps_per_sec": sps,
            "min": stats["min"], "noise_band": stats["noise_band"],
            "speedup_vs_host_loop": sps / host_sps})
    return rows, summary


def _learner_summary(rows: list) -> dict:
    """Parse ``bench_learner_paths`` csv rows into the BENCH json payload."""
    out = {}
    for row in rows[1:]:
        name, sessions, secs, sps, speedup = row.split(",")
        out[name] = {"sessions": int(sessions),
                     "inner_loop_seconds": float(secs),
                     "session_steps_per_sec": float(sps),
                     "speedup_vs_pergather": float(speedup)}
    return out


# ---------------------------------------------------------------------------
# Scaling: the streaming chunked fleet runtime, 16 -> 1024 sessions
# ---------------------------------------------------------------------------

#: Established run-to-run throughput band of the identical engine on the CI
#: box (session-steps/s at 64 sessions): BENCH_0 measured 63.3, BENCH_1 55.1.
STEADY_STATE_BAND_64 = (55.0, 63.5)


def _scaling_fleet(n: int, chunk, updates: int,
                   overlap: bool = True) -> FleetTuner:
    """Fleet for ``n`` sessions. Grids of >= 64 sessions split over TWO
    workloads, smaller ones use one — the sweep deliberately spans >= 2 grid
    shapes so the compile-reuse claim (one chunk executable serves every
    grid shape) is exercised by measurement, not construction."""
    workloads = ["seq_write"] if n < 64 else ["seq_write", "file_server"]
    cfg = DDPGConfig.for_env(LustreSimEnv("seq_write"),
                             updates_per_step=updates)
    return FleetTuner.from_grid(
        workloads, [{"throughput": 1.0}], list(range(n // len(workloads))),
        engine="scan", ddpg_config=cfg, eval_runs=1, chunk=chunk,
        overlap=overlap)


def bench_overlap_ab(n: int, chunk: int, steps: int, updates: int = 96,
                     repeats: int = 1) -> tuple:
    """Double-buffered chunk staging A/B: the same fleet, overlap off vs on.

    ``overlap=False`` is the pre-overlap serial schedule (stage -> compute ->
    drain per chunk); ``overlap=True`` hides host->device staging and host
    trace decode under the previous chunk's compute. Outputs are bitwise
    identical (pinned by tests/test_chunked_fleet.py) — this measures the
    wall-clock difference only. Returns (csv rows, summary fragment)."""
    rows = [csv_row("overlap", "sessions", "chunks", "sps_median", "sps_min",
                    "noise_band")]
    out = {"sessions": n, "chunk": chunk, "steps": steps, "updates": updates}
    from repro.core.episode import last_fleet_run_stats
    for overlap in (False, True):
        fleet = _scaling_fleet(n, chunk, updates, overlap=overlap)
        fleet.precompile(steps)

        def one():
            t0 = time.perf_counter()
            fleet.run(steps)
            return steps * n / (time.perf_counter() - t0)

        meas = repeat_measure(one, repeats)
        stats = last_fleet_run_stats()
        assert stats["overlap"] == overlap
        key = "on" if overlap else "off"
        out[key] = {"session_steps_per_sec": meas["median"],
                    "min": meas["min"], "noise_band": meas["noise_band"],
                    "peak_device_bytes": stats["peak_device_bytes"],
                    "staging": dict(stats.get("staging", {}))}
        rows.append(csv_row(key, n, stats["num_chunks"],
                            f"{meas['median']:.2f}", f"{meas['min']:.2f}",
                            f"{meas['noise_band']:.3f}"))
    out["speedup_on_vs_off"] = (out["on"]["session_steps_per_sec"]
                                / out["off"]["session_steps_per_sec"])
    rows.append(csv_row("speedup_on_vs_off",
                        f"{out['speedup_on_vs_off']:.2f}", "", "", "", ""))
    eff = out["on"]["staging"].get("overlap_efficiency")
    if eff is not None:
        rows.append(csv_row("overlap_efficiency", f"{eff:.3f}", "", "", "",
                            ""))
    return rows, out


def bench_service(n: int, chunk: int, steps: int, updates: int = 96,
                  repeats: int = 1) -> tuple:
    """Service-mode throughput: the persistent ``FleetService`` driving the
    same session population through its leased-slot chunk loop.

    Measures ``advance(steps)`` rounds on a standing fleet — the serving-
    loop overhead (per-session host state, boundary restaging, lease
    bookkeeping) relative to the batch ``FleetTuner`` numbers above.
    Returns (csv rows, summary fragment)."""
    from repro.core import FleetService

    cfg = DDPGConfig.for_env(LustreSimEnv("seq_write"),
                             updates_per_step=updates)
    svc = FleetService(chunk=chunk, ddpg_config=cfg, eval_runs=1)
    for i in range(n):
        svc.request_join("seq_write", {"throughput": 1.0}, i)
    svc.advance(steps)  # lease + warm the chunk executable

    def one():
        t0 = time.perf_counter()
        svc.advance(steps)
        return steps * n / (time.perf_counter() - t0)

    meas = repeat_measure(one, repeats)
    stats = {k: v for k, v in svc.last_stats.items() if k != "program"}
    rows = [csv_row("mode", "sessions", "chunks", "sps_median", "sps_min",
                    "noise_band"),
            csv_row("service", n, stats["num_chunks"],
                    f"{meas['median']:.2f}", f"{meas['min']:.2f}",
                    f"{meas['noise_band']:.3f}")]
    return rows, {
        "sessions": n, "chunk": chunk, "steps": steps, "updates": updates,
        "session_steps_per_sec": meas["median"], "min": meas["min"],
        "noise_band": meas["noise_band"],
        "peak_device_bytes": stats["peak_device_bytes"],
        "executable_cache_size": stats["executable_cache_size"],
    }


def bench_scaling(session_counts: list, chunk: int, steps: int,
                  updates: int = 96, repeats: int = 1) -> tuple:
    """Streaming chunked runtime across fleet sizes + the monolithic control.

    For every N the WHOLE fleet runs as ceil(N / chunk) chunks through one
    compiled episode program; recorded per point: session-steps/s
    (median over ``repeats``, with the noise band), end-to-end wall clock,
    and the measured peak resident device bytes per session
    (``core.episode.last_fleet_run_stats`` — sampled live-array bytes, not
    an estimate). The monolithic control re-runs the largest-but-64 fleet at
    chunk=None (one chunk of all 64 sessions, the pre-streaming schedule) to
    measure the device footprint the chunked runtime removes; it runs LAST
    so its [64]-shaped bucket cannot pollute the sweep's compile count.

    Returns (csv rows, summary dict for BENCH_<n>.json).
    """
    from repro.core.episode import last_fleet_run_stats

    rows = [csv_row("sessions", "grid", "chunks", "sps_median", "sps_min",
                    "noise_band", "peak_bytes_per_session", "wall_s_median")]
    points, program_ids, cache_sizes, grid_shapes = [], set(), [], set()
    for n in session_counts:
        fleet = _scaling_fleet(n, chunk, updates)
        n_workloads = len(set(l.split("|")[0] for l in fleet.labels))
        grid_shapes.add((n_workloads, len(fleet.labels)))
        grid_label = f"{n_workloads}w-{len(fleet.labels)}cells"
        fleet.precompile(steps)

        def one():
            t0 = time.perf_counter()
            fleet.run(steps)
            return steps * n / (time.perf_counter() - t0)

        meas = repeat_measure(one, repeats)
        stats = last_fleet_run_stats()
        program_ids.add(id(stats["program"]))
        cache_sizes.append(stats["executable_cache_size"])
        wall = steps * n / meas["median"]
        per_session = stats["peak_device_bytes"] / n
        points.append({
            "sessions": n,
            "grid": grid_label,
            "chunks": stats["num_chunks"],
            "session_steps_per_sec": meas["median"],
            "session_steps_per_sec_min": meas["min"],
            "noise_band": meas["noise_band"],
            "wall_seconds": wall,
            "peak_device_bytes": stats["peak_device_bytes"],
            "peak_device_bytes_per_session": per_session,
        })
        rows.append(csv_row(n, points[-1]["grid"], stats["num_chunks"],
                            f"{meas['median']:.2f}", f"{meas['min']:.2f}",
                            f"{meas['noise_band']:.3f}",
                            f"{per_session:.0f}", f"{wall:.1f}"))

    # monolithic control: 64 sessions, one chunk of all 64 (runs after the
    # sweep so its extra shape bucket never counts against the sweep)
    mono = _scaling_fleet(64, None, updates)
    mono.precompile(steps)

    def one_mono():
        t0 = time.perf_counter()
        mono.run(steps)
        return steps * 64 / (time.perf_counter() - t0)

    mono_meas = repeat_measure(one_mono, repeats)
    mono_stats = last_fleet_run_stats()
    mono_point = {
        "sessions": 64, "chunks": mono_stats["num_chunks"],
        "session_steps_per_sec": mono_meas["median"],
        "noise_band": mono_meas["noise_band"],
        "peak_device_bytes": mono_stats["peak_device_bytes"],
        "peak_device_bytes_per_session": mono_stats["peak_device_bytes"] / 64,
    }
    rows.append(csv_row("64(monolithic)", "2w-64cells", 1,
                        f"{mono_meas['median']:.2f}", f"{mono_meas['min']:.2f}",
                        f"{mono_meas['noise_band']:.3f}",
                        f"{mono_point['peak_device_bytes_per_session']:.0f}",
                        f"{steps * 64 / mono_meas['median']:.1f}"))

    largest = points[-1]
    summary = {
        "benchmark": "fleet_scaling",
        "chunk": chunk, "steps": steps, "updates": updates,
        "repeats": repeats,
        "scaling": points,
        "monolithic_64": mono_point,
        "memory_ratio_monolithic64_vs_largest": (
            mono_point["peak_device_bytes_per_session"]
            / largest["peak_device_bytes_per_session"]),
        "compile": {
            "shared_executable": len(program_ids) == 1,
            "executables_during_sweep": max(cache_sizes),
            "grid_shapes": len(grid_shapes),
        },
    }
    p64 = next((p for p in points if p["sessions"] == 64), None)
    if p64 is not None:
        lo, hi = STEADY_STATE_BAND_64
        summary["steady_state_64"] = {
            "session_steps_per_sec": p64["session_steps_per_sec"],
            "established_band": [lo, hi],
            "within_established_band": bool(
                lo <= p64["session_steps_per_sec"] <= hi),
            # the band was established on BENCH_0/1's single-workload fleet;
            # the monolithic control below runs THIS sweep's exact grid, so
            # its ratio is the composition-controlled chunking cost
            "chunked_vs_monolithic_same_grid": (
                p64["session_steps_per_sec"]
                / mono_point["session_steps_per_sec"]),
        }
    return rows, summary


def scaling_summary(quick: bool = False, repeats: int = None) -> dict:
    """BENCH_<n>.json payload for the scaling benchmark (reuses the
    measurements of a preceding same-``repeats`` ``run_scaling`` call in
    this process)."""
    key = ("scaling", quick, repeats)
    if key in _LAST_RESULTS:
        summary = _LAST_RESULTS[key]
    else:
        _, summary = _run_scaling_measure(quick, repeats)
        _LAST_RESULTS[key] = summary
    summary = dict(summary, quick=quick)
    summary.update(_scaling_fragments(quick, repeats))
    p64 = next((p for p in summary["scaling"] if p["sessions"] == 64), None)
    if p64 is not None:
        # the trajectory series' canonical key (64-session steady state), so
        # every future BENCH point can compare against this one regardless
        # of payload kind
        summary["fleet_session_steps_per_sec"] = p64["session_steps_per_sec"]
    prev = _previous_bench()
    if prev is not None and not quick:
        prev_sps = prev.get("fleet_session_steps_per_sec")
        if prev_sps and p64:
            summary["vs_previous_bench"] = vs_previous(
                {"median": p64["session_steps_per_sec"],
                 "noise_band": p64["noise_band"]}, prev_sps, prev["_file"])
    return summary


def _scaling_fragments(quick: bool, repeats: int = None) -> dict:
    """Overlap A/B + service-mode fragments riding along in the scaling
    BENCH point (cached so a csv run and the json summary measure once)."""
    key = ("scaling_frag", quick, repeats)
    if key not in _LAST_RESULTS:
        if quick:
            _, ab = bench_overlap_ab(256, chunk=8, steps=2, updates=24,
                                     repeats=repeats or 1)
            _, svc = bench_service(32, chunk=8, steps=2, updates=24,
                                   repeats=repeats or 1)
        else:
            # A/B at the sweep's largest size — that is where the synchronous
            # staging dip lived; service point at 256 to bound join cost
            _, ab = bench_overlap_ab(1024, chunk=16, steps=5, updates=96,
                                     repeats=repeats or 1)
            _, svc = bench_service(256, chunk=16, steps=5, updates=96,
                                   repeats=repeats or 3)
        _LAST_RESULTS[key] = {"overlap_ab": ab, "service_mode": svc}
    return _LAST_RESULTS[key]


def _run_scaling_measure(quick: bool, repeats: int = None) -> tuple:
    if quick:
        return bench_scaling([16, 256], chunk=8, steps=2, updates=24,
                             repeats=repeats or 1)
    return bench_scaling([16, 64, 256, 1024], chunk=16, steps=5, updates=96,
                         repeats=repeats or 3)


def run_scaling(quick: bool = False, repeats: int = None) -> list:
    rows, summary = _run_scaling_measure(quick, repeats)
    _LAST_RESULTS[("scaling", quick, repeats)] = summary
    return rows


# Measurements from the most recent run(quick) call, keyed by ``quick`` —
# episode_summary reuses them so the csv table and the BENCH_<n>.json point
# come from ONE measurement instead of re-timing (the CI box has 10-15%
# run-to-run variance; duplicate timing would let the two outputs disagree).
_LAST_RESULTS: dict = {}


def episode_summary(quick: bool = False) -> dict:
    """BENCH_<n>.json payload: the episode-engine perf trajectory point,
    plus the learner-formulation comparison and — when a previous
    ``BENCH_<n>.json`` exists at the repo root — the measured ratio against
    its recorded fleet throughput (same box or not, the raw numbers are
    both preserved, so the comparison is auditable). Reuses the measurements
    of a preceding ``run(quick)`` call in this process, measuring only if
    none exist."""
    if quick in _LAST_RESULTS:
        summary, learner_rows = _LAST_RESULTS[quick]
    elif quick:
        _, summary = bench_episode_engine([8], steps=3, updates=24)
        learner_rows = bench_learner_paths(8, updates=24, reps=2)
    else:
        _, summary = bench_episode_engine([16, 64], steps=5, updates=96)
        learner_rows = bench_learner_paths(64, updates=96)
    top = summary["fleets"][-1]
    payload = {
        "benchmark": "episode_engine",
        "quick": quick,
        "host_loop_steps_per_sec": summary["host_loop_steps_per_sec"],
        "single_scan_steps_per_sec": summary["single_scan_steps_per_sec"],
        "fleet_size": top["sessions"],
        "fleet_session_steps_per_sec": top["session_steps_per_sec"],
        "fleet_session_steps_per_sec_min": top.get(
            "min", top["session_steps_per_sec"]),
        "noise_band": top.get("noise_band"),
        "speedup_vs_host_loop": top["speedup_vs_host_loop"],
        "fleets": summary["fleets"],
        "learner_paths": _learner_summary(learner_rows),
    }
    prev = _previous_bench()
    if prev is not None and not quick:
        prev_sps = prev.get("fleet_session_steps_per_sec")
        if prev_sps:
            payload["vs_previous_bench"] = vs_previous(
                {"median": top["session_steps_per_sec"],
                 "noise_band": top.get("noise_band", 0.0)},
                prev_sps, prev["_file"])
    return payload


def _previous_bench() -> dict:
    """Latest FULL-mode repo-root BENCH_<n>.json, or None.

    Quick-mode points (``"quick": true`` — smaller fleets, fewer updates)
    are skipped: a 64-session/96-update throughput divided by an
    8-session/24-update one would be a meaningless trajectory ratio."""
    import json
    import os
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    latest, n = None, 0
    while os.path.exists(os.path.join(root, f"BENCH_{n}.json")):
        with open(os.path.join(root, f"BENCH_{n}.json")) as f:
            point = json.load(f)
        if not point.get("quick"):
            point["_file"] = f"BENCH_{n}.json"
            latest = point
        n += 1
    return latest


def run(quick: bool = False, repeats: int = 1) -> list:
    if quick:
        rows = bench_learn_paths(env_steps=3, updates=24)
        rows += [""] + bench_dimensionality(env_steps=3, updates=24)
        rows += [""] + bench_fleet_scaling([1, 4], steps=2)
        learner_rows = bench_learner_paths(8, updates=24, reps=2)
        erows, summary = bench_episode_engine([8], steps=3, updates=24,
                                              repeats=repeats)
    else:
        rows = bench_learn_paths(env_steps=10, updates=96)
        rows += [""] + bench_dimensionality(env_steps=10, updates=96)
        rows += [""] + bench_fleet_scaling([1, 4, 8, 16], steps=5)
        learner_rows = bench_learner_paths(64, updates=96)
        erows, summary = bench_episode_engine([16, 64], steps=5, updates=96,
                                              repeats=repeats)
    _LAST_RESULTS[quick] = (summary, learner_rows)
    return rows + [""] + learner_rows + [""] + erows


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--quick", action="store_true",
                        help="small sizes for CI smoke runs")
    parser.add_argument("--repeats", type=int, default=1,
                        help="timed repetitions per measurement (median + "
                        "min + noise band recorded)")
    parser.add_argument("--scaling", action="store_true",
                        help="run the chunked-runtime scaling benchmark "
                        "instead of the fleet/learner set")
    parser.add_argument("--service", action="store_true",
                        help="run the persistent-FleetService throughput "
                        "benchmark (advance() rounds on a standing fleet)")
    parser.add_argument("--overlap-ab", action="store_true",
                        help="run the double-buffered staging A/B "
                        "(overlap off vs on, bitwise-identical outputs)")
    args = parser.parse_args()
    if args.service:
        n, c, s, u = (32, 8, 2, 24) if args.quick else (256, 16, 5, 96)
        rows, _ = bench_service(n, c, s, u, repeats=args.repeats)
        print("\n".join(rows))
    elif args.overlap_ab:
        n, c, s, u = (256, 8, 2, 24) if args.quick else (1024, 16, 5, 96)
        rows, _ = bench_overlap_ab(n, c, s, u, repeats=args.repeats)
        print("\n".join(rows))
    elif args.scaling:
        print("\n".join(run_scaling(quick=args.quick, repeats=args.repeats)))
    else:
        print("\n".join(run(quick=args.quick, repeats=args.repeats)))
