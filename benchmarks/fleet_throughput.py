"""Fleet-tuning performance: fused scan learner + vmapped multi-session fleet.

Two measurements back the fleet subsystem's perf claims:

  1. ``learn()`` path — per-environment-step model-update time for the legacy
     path (``updates_per_step`` separate jitted dispatches + a host round-trip
     per minibatch sample) vs the fused path (on-device sampling + one
     ``lax.scan`` dispatch). The paper's Table III reports 0.72 s per model
     update on an RTX 5000; the fused path collapses the dispatch overhead
     that dominates at this model size.
  2. Dimensionality — fused learn step on the paper's 2-D space vs the
     8-knob ``LustreSimV2`` space (must stay within ~1.2x: the step is
     dispatch-dominated, so higher-dimensional spaces cost tuning steps,
     not per-step wall clock).
  3. Fleet scaling — wall time per tuning step for N concurrent sessions
     (vmapped learner + vectorized response surface) vs N sequential
     single-session tuners.

Usage:
    PYTHONPATH=src python benchmarks/fleet_throughput.py [--quick]
"""

from __future__ import annotations

import argparse
import time

import numpy as np

from benchmarks.common import csv_row
from repro.core import DDPGConfig, FleetTuner, MagpieAgent, Scalarizer, Tuner
from repro.envs import LustreSimEnv, LustreSimV2


def _fill_buffer(agent: MagpieAgent, n: int, rng: np.random.Generator) -> None:
    k, m = agent.cfg.state_dim, agent.cfg.action_dim
    for _ in range(n):
        agent.observe(rng.random(k).astype(np.float32),
                      rng.random(m).astype(np.float32),
                      float(rng.standard_normal() * 0.1),
                      rng.random(k).astype(np.float32))


def bench_learn_paths(env_steps: int, updates: int) -> list:
    """Per-step learn() time: legacy dispatch loop vs fused scan."""
    env = LustreSimEnv("seq_write", seed=0)
    cfg = DDPGConfig(state_dim=env.state_dim, action_dim=env.action_dim,
                     updates_per_step=updates)
    rng = np.random.default_rng(0)
    rows = [csv_row("path", "per_step_seconds", "dispatches_per_step",
                    "speedup_vs_legacy")]

    times = {}
    for fused in (False, True):
        agent = MagpieAgent(cfg, seed=0)
        _fill_buffer(agent, 32, np.random.default_rng(1))
        agent.learn(fused=fused)  # warm up compilation outside the timer
        t0 = time.perf_counter()
        for _ in range(env_steps):
            _fill_buffer(agent, 1, rng)
            agent.learn(fused=fused)
        times[fused] = (time.perf_counter() - t0) / env_steps

    rows.append(csv_row("legacy_per_update_dispatch", f"{times[False]:.4f}",
                        updates, "1.0"))
    rows.append(csv_row("fused_learn_scan", f"{times[True]:.4f}", 1,
                        f"{times[False] / times[True]:.1f}"))
    return rows


def bench_dimensionality(env_steps: int, updates: int) -> list:
    """Fused learn step cost: paper 2-D space vs the 8-knob V2 space.

    The learner is sized from each space via ``DDPGConfig.for_env`` (same
    hidden trunk, wider action head at 8-D). The fused ``lax.scan`` step is
    dispatch-dominated at this model size, so growing the space 2-D -> 8-D
    must stay within ~1.2x per-step time — dimensionality costs tuning steps
    (sample complexity), not wall clock per step.
    """
    rng = np.random.default_rng(0)
    rows = [csv_row("space", "action_dim", "per_step_seconds",
                    "ratio_vs_2d")]
    times = {}
    for name, env in (("paper_2d", LustreSimEnv("seq_write", seed=0)),
                      ("magpie8_8d", LustreSimV2("seq_write", seed=0))):
        cfg = DDPGConfig.for_env(env, updates_per_step=updates)
        agent = MagpieAgent(cfg, seed=0)
        _fill_buffer(agent, 32, np.random.default_rng(1))
        agent.learn()  # warm up compilation outside the timer
        t0 = time.perf_counter()
        for _ in range(env_steps):
            _fill_buffer(agent, 1, rng)
            agent.learn()
        times[name] = (time.perf_counter() - t0) / env_steps
        rows.append(csv_row(
            name, cfg.action_dim, f"{times[name]:.4f}",
            f"{times[name] / times['paper_2d']:.2f}"))
    return rows


def bench_fleet_scaling(fleet_sizes: list, steps: int) -> list:
    """Fleet step time vs equivalent sequential single-session tuning."""
    rows = [csv_row("sessions", "fleet_seconds_per_step",
                    "sequential_seconds_per_step", "speedup")]
    for n in fleet_sizes:
        seeds = list(range(n))
        fleet = FleetTuner.from_grid(["seq_write"], [{"throughput": 1.0}],
                                     seeds, eval_runs=1)
        fleet.run(1)  # warm up compilation for this fleet width
        t0 = time.perf_counter()
        fleet.run(steps)
        fleet_t = (time.perf_counter() - t0) / steps

        tuners = []
        for seed in seeds:
            env = LustreSimEnv("seq_write", seed=seed)
            scal = Scalarizer(weights={"throughput": 1.0},
                              specs=env.metric_specs)
            agent = MagpieAgent(DDPGConfig(state_dim=env.state_dim,
                                           action_dim=env.action_dim),
                                seed=seed)
            tuners.append(Tuner(env, scal, agent, eval_runs=1))
        for t in tuners:
            t.run(1)  # warm up
        t0 = time.perf_counter()
        for t in tuners:
            t.run(steps)
        seq_t = (time.perf_counter() - t0) / steps

        rows.append(csv_row(n, f"{fleet_t:.4f}", f"{seq_t:.4f}",
                            f"{seq_t / fleet_t:.1f}"))
    return rows


class _LegacyAgent(MagpieAgent):
    """The step-by-step host learner: ``updates_per_step`` separate jitted
    dispatches + a host minibatch sample per update — the paper's Table III
    per-iteration architecture, and the reference 'host loop' the episode
    engine is measured against."""

    def learn(self, updates=None):
        return super().learn(updates=updates, fused=False)


def _scan_tuner(workload: str, seed: int, updates: int, engine: str,
                legacy: bool = False) -> Tuner:
    env = LustreSimEnv(workload, seed=seed).to_model_env()
    scal = Scalarizer(weights={"throughput": 1.0}, specs=env.metric_specs)
    agent_cls = _LegacyAgent if legacy else MagpieAgent
    agent = agent_cls(DDPGConfig.for_env(env, updates_per_step=updates),
                      seed=seed)
    return Tuner(env, scal, agent, eval_runs=1, engine=engine)


def bench_episode_engine(fleet_sizes: list, steps: int,
                         updates: int = 96) -> tuple:
    """Whole-episode engine vs the host loop, on the same pure env model.

    Three rungs, same algorithm and budget on every one:

      host_loop        the step-by-step Fig. 1 loop with per-minibatch learner
                       dispatches (Table III's architecture) — the baseline
      host_loop_fused  the loop with the PR-1 fused ``ddpg_learn_scan``
                       (one learn dispatch per step, still one host round
                       trip per act/env/learn)
      episode_scan /   this PR: the whole episode (act → env → reward →
      fleet_scan       store → learn) as ONE XLA program, then N sessions
                       vmapped on a fleet axis

    Throughput is session-steps/second; ``speedup_vs_host`` is against
    ``host_loop``. Each configuration is warmed at the measured step count so
    compilation never lands in the timer. Returns (csv rows, summary dict) —
    the summary feeds the repo-root BENCH_<n>.json trajectory file.
    """
    rows = [csv_row("engine", "sessions", "session_steps_per_sec",
                    "speedup_vs_host")]

    def timed(tuner):
        tuner.run(steps)  # warm compilation at this episode length
        t0 = time.perf_counter()
        tuner.run(steps)
        return steps / (time.perf_counter() - t0)

    host_sps = timed(_scan_tuner("seq_write", 0, updates, "host", legacy=True))
    rows.append(csv_row("host_loop", 1, f"{host_sps:.2f}", "1.0"))

    fused_sps = timed(_scan_tuner("seq_write", 0, updates, "host"))
    rows.append(csv_row("host_loop_fused", 1, f"{fused_sps:.2f}",
                        f"{fused_sps / host_sps:.1f}"))

    scan_sps = timed(_scan_tuner("seq_write", 0, updates, "scan"))
    rows.append(csv_row("episode_scan", 1, f"{scan_sps:.2f}",
                        f"{scan_sps / host_sps:.1f}"))

    summary = {"host_loop_steps_per_sec": host_sps,
               "host_loop_fused_steps_per_sec": fused_sps,
               "single_scan_steps_per_sec": scan_sps, "fleets": []}
    for n in fleet_sizes:
        cfg = DDPGConfig.for_env(LustreSimEnv("seq_write"),
                                 updates_per_step=updates)
        fleet = FleetTuner.from_grid(
            ["seq_write"], [{"throughput": 1.0}], list(range(n)),
            engine="scan", ddpg_config=cfg, eval_runs=1)
        fleet.run(steps)
        t0 = time.perf_counter()
        fleet.run(steps)
        sps = steps * n / (time.perf_counter() - t0)
        rows.append(csv_row("fleet_scan", n, f"{sps:.2f}",
                            f"{sps / host_sps:.1f}"))
        summary["fleets"].append({"sessions": n, "session_steps_per_sec": sps,
                                  "speedup_vs_host_loop": sps / host_sps})
    return rows, summary


def episode_summary(quick: bool = False) -> dict:
    """BENCH_<n>.json payload: the episode-engine perf trajectory point."""
    if quick:
        _, summary = bench_episode_engine([8], steps=3, updates=24)
    else:
        _, summary = bench_episode_engine([16, 64], steps=5, updates=96)
    top = summary["fleets"][-1]
    return {
        "benchmark": "episode_engine",
        "quick": quick,
        "host_loop_steps_per_sec": summary["host_loop_steps_per_sec"],
        "single_scan_steps_per_sec": summary["single_scan_steps_per_sec"],
        "fleet_size": top["sessions"],
        "fleet_session_steps_per_sec": top["session_steps_per_sec"],
        "speedup_vs_host_loop": top["speedup_vs_host_loop"],
        "fleets": summary["fleets"],
    }


def run(quick: bool = False) -> list:
    if quick:
        rows = bench_learn_paths(env_steps=3, updates=24)
        rows += [""] + bench_dimensionality(env_steps=3, updates=24)
        rows += [""] + bench_fleet_scaling([1, 4], steps=2)
        erows, _ = bench_episode_engine([8], steps=3, updates=24)
    else:
        rows = bench_learn_paths(env_steps=10, updates=96)
        rows += [""] + bench_dimensionality(env_steps=10, updates=96)
        rows += [""] + bench_fleet_scaling([1, 4, 8, 16], steps=5)
        erows, _ = bench_episode_engine([16, 64], steps=5, updates=96)
    return rows + [""] + erows


if __name__ == "__main__":
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--quick", action="store_true",
                        help="small sizes for CI smoke runs")
    args = parser.parse_args()
    print("\n".join(run(quick=args.quick)))
